"""repro.analyze: static config feasibility (accepted implies builds for
every registered kernel; seeded known-bad configs rejected with stable
reason codes; statically-infeasible store records never cost a build) and
the REP101-REP104 concurrency lint — fixtures mirror the real findings
fixed on this tree, and the tree itself must lint clean."""

import json
import textwrap

import jax
import numpy as np
import pytest

from repro.analyze.feasibility import (
    FEASIBLE,
    PositiveIntTiles,
    check_config,
    feasibility_filter,
    kernel_rules,
    register_rules,
)
from repro.analyze.lint import lint_paths, lint_source
from repro.core import EvalResult
from repro.core.search import BayesianSearch
from repro.core.space import ConfigurationSpace, Ordinal
from repro.dispatch import DispatchService, TuningRecord, TuningStore, register
from repro.engine import Campaign
from repro.fleet import Replica
from repro.kernels.problems import BENCH_DIMS, LARGE_SHAPES, bench_problem
from repro.kernels.spaces import KERNEL_SPACES, kernel_space
from repro.launch.analyze import main as analyze_main


# ---------------------------------------------------------------------------
# feasibility: the zero-false-positive property
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kernel", sorted(KERNEL_SPACES))
def test_accepted_sampled_configs_build(kernel):
    """Every config the feasibility pass accepts at bench dims must survive
    the real builder + an abstract trace — accepted implies builds. This is
    the contract that lets the search path prune and DispatchService
    quarantine on static judgment alone."""
    space = kernel_space(kernel, target="host", seed=5)
    rng = np.random.default_rng(5)
    cfgs = [space.default_configuration()] + space.sample_configurations(4, rng)
    factory = bench_problem(kernel)
    dims = BENCH_DIMS[kernel]
    accepted = 0
    for cfg in cfgs:
        if not check_config(kernel, cfg, dims=dims, target="host").ok:
            continue
        fn, args = factory(cfg)
        jax.eval_shape(fn, *args)   # must not raise
        accepted += 1
    assert accepted, "the sampled space produced no accepted configs to audit"


@pytest.mark.parametrize("kernel,cfg,dims,target,code", [
    ("syr2k", {}, BENCH_DIMS["syr2k"], "host", "missing_param:bi"),
    ("syr2k", {"bi": 0, "bj": 64, "bk": 64}, BENCH_DIMS["syr2k"], "host",
     "tile_not_positive:bi"),
    ("syr2k", {"bi": 2.5, "bj": 64, "bk": 64}, BENCH_DIMS["syr2k"], "host",
     "tile_not_int:bi"),
    ("flash_attention", {"impl": "triton", "bq": 128, "bk": 128},
     BENCH_DIMS["flash_attention"], "host", "invalid_choice:impl"),
    ("heat3d", {"bi": 8, "fuse_t": 3}, (40, 8), "host",
     "fuse_indivisible:fuse_t"),
    ("heat3d", {"bi": 8, "fuse_t": 0}, (40, 8), "host",
     "fuse_not_positive:fuse_t"),
    ("flash_attention", {"impl": "xla", "bq": 1024, "bk": 128},
     LARGE_SHAPES["flash_attention"], "cost", "vmem_overflow"),
    ("decode_attention", {"impl": "cuda", "bk": 128, "hg": 1, "page": 128},
     BENCH_DIMS["decode_attention"], "host", "invalid_choice:impl"),
    # the paged layout contract: the signature's seq is the cache bucket,
    # always a whole multiple of the record's page — 48 never divides 128
    ("decode_attention", {"impl": "xla", "bk": 128, "hg": 1, "page": 48},
     BENCH_DIMS["decode_attention"], "host", "page_indivisible:page"),
])
def test_known_bad_configs_rejected_with_stable_codes(
        kernel, cfg, dims, target, code):
    v = check_config(kernel, cfg, dims=dims, target=target)
    assert not v.ok
    assert code in v.reasons
    assert code in v.reason()   # the quarantine-record form


def test_warnings_do_not_reject():
    # the paper's Floyd-Warshall pathology analog: syr2k host tiles from
    # mixed families pad N=240 to lcm(50,128)=3200 — pathological but it
    # builds, so it must warn, not error
    v = check_config("syr2k", {"bi": 50, "bj": 128, "bk": 64},
                     dims=BENCH_DIMS["syr2k"], target="host")
    assert v.ok
    assert "padding_waste" in {f.code for f in v.warnings}


def test_unknown_kernel_is_feasible():
    # kernels with no registered rules (toy test kernels, third-party
    # registrations) are never guessed about
    assert check_config("no_such_kernel", {"whatever": -1}) is FEASIBLE
    assert kernel_rules("no_such_kernel") == ()
    assert feasibility_filter("no_such_kernel") is None


def test_signature_derived_dims_match_explicit_dims():
    from repro.kernels.problems import problem_signature_for

    sig = problem_signature_for("heat3d", "host")
    bad = {"bi": 8, "fuse_t": 3}
    by_sig = check_config("heat3d", bad, signature=sig, target="host")
    by_dims = check_config("heat3d", bad, dims=BENCH_DIMS["heat3d"],
                           target="host")
    assert by_sig.reasons == by_dims.reasons == ("fuse_indivisible:fuse_t",)


def test_feasibility_filter_prunes_errors_keeps_warnings():
    accept = feasibility_filter("syr2k", dims=BENCH_DIMS["syr2k"],
                                target="host")
    assert accept({"bi": 16, "bj": 16, "bk": 16})
    assert accept({"bi": 50, "bj": 128, "bk": 64})   # warn-only: keep
    assert not accept({"bi": 0, "bj": 16, "bk": 16})
    assert not accept({"bj": 16, "bk": 16})          # missing bi


def test_register_rules_appends_then_replaces():
    name = "anlz_custom_kernel"
    try:
        register_rules(name, [PositiveIntTiles("t")])
        assert not check_config(name, {"t": -1}).ok
        register_rules(name, [], replace=True)
        assert check_config(name, {"t": -1}).ok
    finally:
        register_rules(name, [], replace=True)


# ---------------------------------------------------------------------------
# search-path integration: pruning before acquisition scoring
# ---------------------------------------------------------------------------

_SCALES = (1, 2, 4, 8, 16, 32)


def _scale_space(seed=0):
    cs = ConfigurationSpace(seed=seed)
    cs.add_hyperparameter(Ordinal("s", _SCALES, default=1))
    return cs


def test_search_prunes_infeasible_from_acquisition_pool():
    s = BayesianSearch(_scale_space(), n_initial=2, n_candidates=64, seed=3,
                       feasibility=lambda c: c["s"] < 16)
    for i in range(10):
        cfg = s.ask()
        if i >= 2:  # init-phase draws are not model proposals
            assert cfg["s"] < 16
        s.tell(cfg, EvalResult(1.0 / cfg["s"], True, {}))
    assert s.n_pruned > 0


def test_search_feasibility_none_and_accept_all_are_identical():
    a = BayesianSearch(_scale_space(), n_initial=2, seed=7)
    b = BayesianSearch(_scale_space(), n_initial=2, seed=7,
                       feasibility=lambda c: True)
    for _ in range(8):
        ca, cb = a.ask(), b.ask()
        assert ca == cb   # the fixed-seed trajectory contract
        a.tell(ca, EvalResult(float(ca["s"]), True, {}))
        b.tell(cb, EvalResult(float(cb["s"]), True, {}))
    assert b.n_pruned == 0


def test_search_all_infeasible_falls_back_to_raw_pool():
    # a predicate that rejects everything must not strand the optimizer:
    # the raw pool survives and proposals keep flowing
    s = BayesianSearch(_scale_space(), n_initial=2, seed=1,
                       feasibility=lambda c: False)
    for _ in range(6):
        cfg = s.ask()
        assert cfg["s"] in _SCALES
        # distinct objectives, or the model phase never builds a pool
        s.tell(cfg, EvalResult(float(cfg["s"]) + len(s.db), True, {}))
    assert s.n_pruned > 0


def test_campaign_surfaces_n_pruned_in_timings():
    res = Campaign(_scale_space(),
                   lambda c: EvalResult(1.0 / c["s"], True, {}),
                   max_evals=8, n_initial=2, seed=0,
                   feasibility=lambda c: c["s"] < 16).run()
    assert res.timings["n_pruned"] > 0
    res2 = Campaign(_scale_space(),
                    lambda c: EvalResult(1.0 / c["s"], True, {}),
                    max_evals=6, n_initial=2, seed=0).run()
    assert res2.timings["n_pruned"] == 0


# ---------------------------------------------------------------------------
# dispatch integration: static infeasibility never costs a build
# ---------------------------------------------------------------------------

_BUILDS = {"n": 0}


def _counting_builder(cfg):
    _BUILDS["n"] += 1
    return lambda x: x * cfg["t"]


def _anlz_space(target="host", seed=1234):
    cs = ConfigurationSpace(seed=seed)
    cs.add_hyperparameter(Ordinal("t", (1, 2, 4, 8), default=1))
    return cs


register("anlz_toy", builder=_counting_builder, space=_anlz_space)
register_rules("anlz_toy", [PositiveIntTiles("t")], replace=True)


def test_dispatch_skips_build_for_statically_infeasible_record(tmp_path):
    store = TuningStore(str(tmp_path / "s"))
    store.put(TuningRecord("anlz_toy", ((4,),), "host", {"t": -2}, 0.5))
    svc = DispatchService(store)
    x = np.arange(4.0)
    before = _BUILDS["n"]
    np.testing.assert_array_equal(np.asarray(svc.call("anlz_toy", x)), x * 1)
    # the poisoned record was rejected on static judgment: exactly one
    # build happened (the default config), and it counts as "infeasible",
    # not "build_failed" — the two failure modes stay distinguishable
    assert _BUILDS["n"] == before + 1
    assert svc.stats["infeasible"] == 1
    assert svc.stats["build_failed"] == 0
    q = store.quarantines("anlz_toy")
    assert len(q) == 1
    assert q[0]["reason"] == "tile_not_positive:t"
    # quarantined: a repeat dispatch falls straight to the default
    svc2 = DispatchService(store)
    np.testing.assert_array_equal(np.asarray(svc2.call("anlz_toy", x)), x * 1)
    assert svc2.stats["infeasible"] == 0   # nothing left to reject


def test_dispatch_feasible_record_still_serves(tmp_path):
    store = TuningStore(str(tmp_path / "s"))
    store.put(TuningRecord("anlz_toy", ((4,),), "host", {"t": 4}, 0.5))
    svc = DispatchService(store)
    x = np.arange(4.0)
    np.testing.assert_array_equal(np.asarray(svc.call("anlz_toy", x)), x * 4)
    assert svc.stats["infeasible"] == 0


def test_quarantine_reason_surfaces_in_fleet_status(tmp_path):
    store = TuningStore(str(tmp_path / "s"))
    rec = TuningRecord("anlz_toy", ((4,),), "host", {"t": -2}, 0.5)
    store.put(rec)
    store.quarantine(rec, reason="tile_not_positive:t")
    st = Replica(store).status()
    assert [q["reason"] for q in st["quarantined"]] == ["tile_not_positive:t"]
    assert st["quarantined"][0]["kernel"] == "anlz_toy"


def test_quarantine_reason_defaults_empty(tmp_path):
    store = TuningStore(str(tmp_path / "s"))
    rec = TuningRecord("anlz_toy", ((4,),), "host", {"t": -2}, 0.5)
    store.put(rec)
    store.quarantine(rec)   # pre-reason call shape stays valid
    assert [q["reason"] for q in store.quarantines()] == [""]


# ---------------------------------------------------------------------------
# concurrency lint: fixtures mirror the real findings fixed on this tree
# ---------------------------------------------------------------------------


def _codes(src):
    return [f.code for f in lint_source(textwrap.dedent(src))]


def test_lint_rep101_wallclock_duration():
    # the SyncAgent lag-math finding: wall-clock difference as a duration
    bad = """
    import time

    class Agent:
        def lag(self):
            return time.time() - self.last_sync
    """
    assert "REP101" in _codes(bad)
    # the applied fix: a monotonic companion stamp
    good = bad.replace("time.time()", "time.monotonic()")
    assert _codes(good) == []


def test_lint_rep101_from_time_import():
    assert "REP101" in _codes("""
    from time import time

    def age(t0):
        return time() - t0
    """)


def test_lint_rep102_unguarded_mutation():
    # the TuningStore.get LRU-touch finding: self._access written under
    # self._tlock in some methods, bare elsewhere
    bad = """
    import threading

    class Store:
        def __init__(self):
            self._tlock = threading.Lock()
            self._access = {}

        def put(self, k, v):
            with self._tlock:
                self._access[k] = v

        def get(self, k):
            self._access[k] = 1   # unguarded
            return k
    """
    assert "REP102" in _codes(bad)
    good = bad.replace("self._access[k] = 1   # unguarded",
                       "with self._tlock:\n                self._access[k] = 1")
    assert _codes(good) == []


def test_lint_rep102_locked_helpers_inherit_protection():
    # *_locked helpers and private helpers only called under the lock are
    # caller-holds-lock by convention — no finding
    assert _codes("""
    import threading

    class Store:
        def __init__(self):
            self._lock = threading.Lock()
            self._n = 0

        def bump(self):
            with self._lock:
                self._n += 1
                self._sweep_locked()

        def _sweep_locked(self):
            self._n = 0
    """) == []


def test_lint_rep103_lock_order_inversion():
    # documented order is store -> fleet: acquiring the TuningStore lock
    # while holding the OpLog lock is an inversion
    bad = """
    class Broker:
        def __init__(self, store: TuningStore, oplog: OpLog):
            self.store = store
            self.oplog = oplog

        def publish(self):
            with self.oplog._lock:
                with self.store._lock:
                    pass
    """
    assert "REP103" in _codes(bad)
    good = """
    class Broker:
        def __init__(self, store: TuningStore, oplog: OpLog):
            self.store = store
            self.oplog = oplog

        def publish(self):
            with self.store._lock:
                with self.oplog._lock:
                    pass
    """
    assert _codes(good) == []


def test_lint_rep103_through_method_call():
    # the inversion through a call: any unlinted TuningStore method may take
    # the store's rank-0 lock while the fleet lock is held
    assert "REP103" in _codes("""
    class Broker:
        def __init__(self, store: TuningStore, oplog: OpLog):
            self.store = store
            self.oplog = oplog

        def publish(self, rec):
            with self.oplog._lock:
                self.store.put(rec)
    """)


def test_lint_rep104_unowned_thread():
    bad = """
    import threading

    class Runner:
        def start(self):
            self._t = threading.Thread(target=self._run)
            self._t.start()
    """
    assert "REP104" in _codes(bad)
    assert _codes(bad.replace("target=self._run",
                              "target=self._run, daemon=True")) == []
    # a stop() handler on the owning class also satisfies the rule
    assert _codes(bad + """
        def stop(self):
            self._t.join()
    """) == []


def test_lint_rep105_runloop_swallow():
    bad = """
    import threading

    class Agent:
        def start(self):
            self._t = threading.Thread(target=self._run, daemon=True)
            self._t.start()

        def _run(self):
            while True:
                try:
                    self.step()
                except Exception:
                    pass
    """
    assert "REP105" in _codes(bad)
    # counting the failure is accounting enough: the daemon stays observable
    assert _codes(bad.replace("pass", 'self.stats["errors"] += 1')) == []
    # so is re-raising after cleanup
    assert _codes(bad.replace("pass", "raise")) == []
    # a narrow except is a deliberate decision, not a swallow
    assert _codes(bad.replace("except Exception:", "except ValueError:")) == []


def test_lint_rep105_reaches_helpers_called_from_the_loop():
    bad = """
    import threading

    class Agent:
        def start(self):
            threading.Thread(target=self._run, daemon=True).start()

        def _run(self):
            while True:
                self._tick()

        def _tick(self):
            try:
                self.sync()
            except Exception:
                return None
    """
    assert "REP105" in _codes(bad)


def test_lint_rep105_ignores_loops_outside_threads():
    src = """
    class Loader:
        def load_all(self, paths):
            out = []
            for p in paths:
                try:
                    out.append(self.parse(p))
                except Exception:
                    continue
            return out
    """
    assert _codes(src) == []


def test_lint_rep105_counter_call_accounts():
    src = """
    import threading

    class Agent:
        def start(self):
            threading.Thread(target=self._run, daemon=True).start()

        def _run(self):
            while True:
                try:
                    self.step()
                except Exception:
                    self.registry.add("errors_total")
    """
    assert _codes(src) == []


def test_lint_pragma_allowlists_a_finding():
    src = """
    import time

    class Rec:
        def age(self):
            # lint: allow=REP101 persisted stamps are cross-process wall-clock
            return time.time() - self.created
    """
    assert _codes(src) == []
    # the pragma only silences the named code
    assert "REP101" in _codes(src.replace("allow=REP101", "allow=REP104"))


def test_lint_tree_is_clean():
    """Tier-1 gate: the codebase holds its own documented concurrency
    invariants. New findings must be fixed or explicitly pragma'd."""
    import os

    import repro

    pkg = os.path.dirname(os.path.abspath(repro.__file__))
    findings = lint_paths([pkg])
    assert findings == [], "\n".join(f.format() for f in findings)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def test_cli_space_json_artifact(tmp_path, capsys):
    out = tmp_path / "space.json"
    rc = analyze_main(["space", "--kernel", "syr2k", "--samples", "16",
                       "--json", "--out", str(out)])
    assert rc == 0
    data = json.loads(out.read_text())
    assert json.loads(capsys.readouterr().out) == data
    kernels = {r["kernel"] for r in data["audit"]}
    targets = {r["target"] for r in data["audit"]}
    assert kernels == {"syr2k"} and targets == {"host", "cost"}
    for row in data["audit"]:
        assert 0.0 <= row["infeasible_fraction"] <= 1.0
        assert row["n_sampled"] == 17   # default config + samples


def test_cli_lint_budget_gates_exit_code(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text("import time\n\n"
                   "class A:\n"
                   "    def age(self):\n"
                   "        return time.time() - self.t0\n")
    assert analyze_main(["lint", str(bad)]) == 1
    assert analyze_main(["lint", str(bad), "--max-findings", "1"]) == 0
    capsys.readouterr()
    rc = analyze_main(["lint", str(bad), "--json"])
    data = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert data["findings"][0]["code"] == "REP101"


def test_cli_lint_clean_tree_exits_zero():
    assert analyze_main(["lint"]) == 0
