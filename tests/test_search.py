"""Bayesian search loop: convergence, learner semantics, fault tolerance,
resume — the paper's Sec 2.2/2.3 behaviors."""

import numpy as np
import pytest

from repro.core import (
    EvalResult,
    PENALTY,
    autotune,
    run_search,
)
from repro.core.database import SKIPPED_DUPLICATE
from repro.core.space import Categorical, ConfigurationSpace, InCondition, Ordinal

TILES = (4, 8, 16, 32, 64, 96, 128)


def small_space(seed=1234):
    cs = ConfigurationSpace(seed=seed)
    cs.add_hyperparameters([
        Categorical("pack", (True, False), default=False),
        Categorical("inter", (True, False), default=False),
        Ordinal("t1", TILES, default=96),
        Ordinal("t2", TILES, default=96),
    ])
    return cs


def objective(cfg) -> float:
    t = 1.0
    t -= 0.3 * bool(cfg["pack"])
    t -= 0.2 * bool(cfg["inter"])
    t += 0.004 * abs(int(cfg["t1"]) - 64)
    t += 0.002 * abs(int(cfg["t2"]) - 32)
    return t


def evaluator(cfg) -> EvalResult:
    return EvalResult(objective(cfg), True, {})


def random_best(n, seed=0):
    cs = small_space(seed)
    rng = np.random.default_rng(seed)
    return min(objective(cs.sample_configuration(rng)) for _ in range(n))


@pytest.mark.parametrize("learner", ["RF", "GBRT"])
def test_bo_beats_random_search(learner):
    res = autotune(small_space(), evaluator, max_evals=50, learner=learner, seed=3)
    rnd = np.mean([random_best(50, s) for s in range(5)])
    assert res.best.objective <= rnd + 1e-9, (res.best.objective, rnd)


def test_bo_finds_near_optimum():
    res = autotune(small_space(), evaluator, max_evals=60, learner="RF", seed=0)
    assert res.best.objective < 0.62  # optimum = 0.5, random mean ~ 1.0


def test_tree_learners_never_reevaluate():
    res = autotune(small_space(), evaluator, max_evals=60, learner="RF", seed=1)
    keys = [tuple(sorted(r.config.items())) for r in res.db.records]
    assert len(keys) == len(set(keys))
    assert res.n_skipped == 0


def test_gp_duplicates_consume_budget():
    """The paper's Fig 6 behavior: GP proposes duplicates, which are skipped
    but still count toward max-evals, so GP performs fewer real evaluations."""
    cs = ConfigurationSpace(seed=0)
    cs.add_hyperparameters([Categorical("a", (0, 1)), Categorical("b", (0, 1))])
    res = autotune(cs, lambda c: EvalResult(float(c["a"] + c["b"]), True, {}),
                   max_evals=30, learner="GP", seed=0, n_initial=4)
    assert len(res.db) == 30            # budget fully consumed...
    assert res.n_evaluated <= 4 + 4     # ...but only ~|space| real evals
    assert res.n_skipped >= 20
    assert any(r.status == SKIPPED_DUPLICATE for r in res.db.records)


def test_failures_are_penalized_not_fatal():
    calls = {"n": 0}

    def flaky(cfg) -> EvalResult:
        calls["n"] += 1
        if bool(cfg["pack"]):
            raise AssertionError("unreachable: evaluator contract")
        return EvalResult(objective(cfg), True, {})

    def guarded(cfg) -> EvalResult:
        if bool(cfg["pack"]):
            return EvalResult(PENALTY, False, {"error": "synthetic compile failure"})
        return EvalResult(objective(cfg), True, {})

    res = autotune(small_space(), guarded, max_evals=40, learner="RF", seed=2)
    assert res.n_failed > 0
    assert res.best is not None and not bool(res.best.config["pack"])
    # the campaign completed the full budget despite failures
    assert len(res.db) == 40


def test_resume_from_database(tmp_path):
    db_path = str(tmp_path / "camp")
    res1 = autotune(small_space(), evaluator, max_evals=15, learner="RF",
                    seed=5, db_path=db_path)
    assert len(res1.db) == 15
    # resume: same path, larger budget -> continues, does not restart
    res2 = autotune(small_space(), evaluator, max_evals=25, learner="RF",
                    seed=5, db_path=db_path)
    assert len(res2.db) == 25
    assert res2.best.objective <= res1.best.objective


def test_conditional_space_searchable():
    cs = ConfigurationSpace(seed=0)
    cs.add_hyperparameters([
        Categorical("pack_a", (True, False), default=False),
        Categorical("pack_b", (True, False), default=False),
        Ordinal("t", TILES, default=96),
    ])
    cs.add_condition(InCondition("pack_b", "pack_a", (True,)))

    def obj(cfg):
        t = 1.0 - 0.2 * bool(cfg["pack_a"]) - 0.3 * bool(cfg.get("pack_b", False))
        return t + 0.001 * int(cfg["t"])

    res = autotune(cs, lambda c: EvalResult(obj(c), True, {}), max_evals=50,
                   learner="RF", seed=0)
    assert res.best.config["pack_a"] is True
    assert res.best.config.get("pack_b") is True


def test_callback_sees_every_record():
    seen = []
    run_search(small_space(), evaluator, max_evals=12, learner="ET", seed=0,
               callback=seen.append)
    assert len(seen) == 12
