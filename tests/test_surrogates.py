"""Surrogate models: fit/predict sanity for all four learners."""

import numpy as np
import pytest

from repro.core.surrogates import (
    LEARNERS,
    ExtraTrees,
    GaussianProcess,
    GradientBoostedTrees,
    RandomForest,
    RegressionTree,
    make_learner,
)


def _toy(n=120, d=4, seed=0, noise=0.01):
    rng = np.random.default_rng(seed)
    X = rng.uniform(0, 1, size=(n, d))
    y = 3 * X[:, 0] + np.sin(4 * X[:, 1]) + 0.5 * X[:, 2] * X[:, 3]
    return X, y + noise * rng.standard_normal(n)


def test_tree_fits_training_data():
    X, y = _toy()
    t = RegressionTree(max_depth=16, min_samples_leaf=1).fit(X, y)
    pred = t.predict(X)
    assert np.mean((pred - y) ** 2) < 0.05 * np.var(y)


def test_tree_constant_target():
    X, _ = _toy(30)
    y = np.full(30, 7.0)
    t = RegressionTree().fit(X, y)
    np.testing.assert_allclose(t.predict(X), 7.0)


@pytest.mark.parametrize("name", LEARNERS)
def test_learner_beats_mean_predictor(name):
    X, y = _toy(150)
    Xte, yte = _toy(60, seed=1)
    model = make_learner(name, seed=0).fit(X, y)
    mu, sigma = model.predict(Xte)
    assert mu.shape == (60,) and sigma.shape == (60,)
    assert np.all(sigma >= 0)
    mse_model = np.mean((mu - yte) ** 2)
    mse_mean = np.mean((y.mean() - yte) ** 2)
    assert mse_model < 0.5 * mse_mean, (name, mse_model, mse_mean)


def test_rf_uncertainty_grows_off_distribution():
    X, y = _toy(100)
    model = RandomForest(seed=0).fit(X, y)
    _, sig_in = model.predict(X[:10])
    _, sig_out = model.predict(np.full((10, X.shape[1]), 5.0))  # far outside
    assert sig_out.mean() >= sig_in.mean()


def test_gbrt_quantiles_ordered():
    X, y = _toy(150, noise=0.3)
    m = GradientBoostedTrees(seed=0)
    m.fit(X, y)
    lo = m.models[0.16].predict(X)
    mid = m.models[0.50].predict(X)
    hi = m.models[0.84].predict(X)
    # quantile ensembles should be ordered on average
    assert (lo <= hi).mean() > 0.9
    assert lo.mean() < mid.mean() < hi.mean()


def test_gp_interpolates_noiseless():
    rng = np.random.default_rng(0)
    X = rng.uniform(0, 1, size=(25, 2))
    y = np.sin(3 * X[:, 0]) + X[:, 1]
    gp = GaussianProcess(noise=1e-6).fit(X, y)
    mu, sigma = gp.predict(X)
    np.testing.assert_allclose(mu, y, atol=5e-2)
    # uncertainty at training points << prior scale
    assert sigma.mean() < 0.5 * y.std()


def test_gp_uncertainty_away_from_data():
    X = np.zeros((10, 2))
    X[:, 0] = np.linspace(0, 1, 10)
    y = X[:, 0] * 2
    gp = GaussianProcess().fit(X, y)
    _, s_near = gp.predict(X)
    _, s_far = gp.predict(np.array([[0.5, 30.0]]))
    assert s_far[0] > s_near.mean()


def test_extra_trees_differ_from_rf():
    X, y = _toy()
    rf = RandomForest(seed=0).fit(X, y)
    et = ExtraTrees(seed=0).fit(X, y)
    mu_rf, _ = rf.predict(X)
    mu_et, _ = et.predict(X)
    assert not np.allclose(mu_rf, mu_et)


def test_make_learner_rejects_unknown():
    with pytest.raises(ValueError):
        make_learner("SVM")
