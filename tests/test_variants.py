"""Host (backend-B1) variants: every timed code mold must equal ref.py."""

import jax
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need it; skip, don't error
from hypothesis import given, settings, strategies as st

from repro.kernels import ref as R
from repro.kernels import variants as V


def _close(got, want, tol=2e-3):
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=tol, rtol=tol)


@settings(max_examples=8, deadline=None)
@given(bm=st.sampled_from([16, 32, 50]), bn=st.sampled_from([16, 32, 50]),
       bk=st.sampled_from([8, 16, 64]), inter=st.booleans(), pack=st.booleans())
def test_blocked_matmul_host_property(bm, bn, bk, inter, pack):
    a = jax.random.normal(jax.random.PRNGKey(0), (70, 50))
    b = jax.random.normal(jax.random.PRNGKey(1), (50, 60))
    got = V.blocked_matmul_host(a, b, bm=bm, bn=bn, bk=bk, interchange=inter,
                                pack=pack)
    _close(got, a @ b, 1e-3)


@pytest.mark.parametrize("cfg", [
    dict(bi=32, bj=32, bk=32),
    dict(bi=50, bj=20, bk=16, interchange=True, pack_a=True, pack_b=True),
])
def test_syr2k_variant(cfg):
    C, A, B = R.init_syr2k(70, 60)
    _close(V.syr2k_variant(C, A, B, 1.5, 1.2, **cfg), R.syr2k_ref(C, A, B), 5e-3)


def test_lu_variant():
    (A,) = R.init_lu(96)
    _close(V.lu_variant(A, bs=20), R.lu_ref(A), 5e-3)


@pytest.mark.parametrize("bi,fuse", [(4, 1), (8, 2)])
def test_heat3d_variant(bi, fuse):
    (A,) = R.init_heat3d(16)
    _close(V.heat3d_variant(A, 2, bi=bi, fuse_t=fuse), R.heat3d_ref(A, 2))


@pytest.mark.parametrize("cfg", [
    dict(bi=16, bj=16, bk=32),
    dict(bi=20, bj=50, bk=16, fuse_center=False, interchange=True),
])
def test_covariance_variant(cfg):
    (d,) = R.init_covariance(84, 40)
    _close(V.covariance_variant(d, **cfg), R.covariance_ref(d))


@pytest.mark.parametrize("cfg", [
    dict(bs=16, unroll=1), dict(bs=20, unroll=4), dict(bs=100, unroll=8),
])
def test_fw_variant(cfg):
    (W,) = R.init_floyd_warshall(60)
    _close(V.floyd_warshall_variant(W, bi=32, bj=32, **cfg),
           R.floyd_warshall_ref(W))


def test_factories_return_timeable_callables():
    C, A, B = R.init_syr2k(40, 30)
    factory = V.syr2k_host((C, A, B))
    fn, args = factory({"bi": 16, "bj": 16, "bk": 16})
    out = jax.jit(fn)(*args)
    _close(out, R.syr2k_ref(C, A, B), 5e-3)


def test_naive_fns_match_ref():
    fns = V.naive_fns()
    C, A, B = R.init_syr2k(40, 30)
    _close(jax.jit(fns["syr2k"])(C, A, B), R.syr2k_ref(C, A, B), 5e-3)
    d = R.init_covariance(50, 30)[0]
    _close(jax.jit(fns["covariance"])(d), R.covariance_ref(d))
    A3 = R.init_mm3(20, 18, 16, 22, 20)
    _close(jax.jit(fns["mm3"])(*A3), R.mm3_ref(*A3), 5e-3)
