"""repro.engine: the unified campaign engine — q=1 batched == legacy serial
trajectories (RF and GP), parallel executors with exact budget accounting and
wall-clock speedup, crash-safe resume from the PerformanceDatabase JSONL,
concurrent TuningStore publication, the single-deadline drain, serve-step
hot-swap on invalidate, and the roofline cost backend."""

import threading
import time

import numpy as np
import pytest

from repro.core import EvalResult
from repro.core.database import FAILED, OK, SKIPPED_DUPLICATE
from repro.core.search import BayesianSearch, run_search
from repro.core.space import Categorical, ConfigurationSpace, Ordinal
from repro.engine import Campaign, InlineExecutor, ThreadExecutor, evaluator_for_spec

TILES = (4, 8, 16, 32, 64, 96, 128)


def small_space(seed=1234):
    cs = ConfigurationSpace(seed=seed)
    cs.add_hyperparameters([
        Categorical("pack", (True, False), default=False),
        Ordinal("t1", TILES, default=96),
        Ordinal("t2", TILES, default=96),
    ])
    return cs


def objective(cfg) -> float:
    return (1.0 - 0.3 * bool(cfg["pack"])
            + 0.004 * abs(int(cfg["t1"]) - 64)
            + 0.002 * abs(int(cfg["t2"]) - 32))


def evaluator(cfg) -> EvalResult:
    return EvalResult(objective(cfg), True, {})


def _records(db):
    return [(r.status, r.config, r.objective) for r in db.records]


def _scale_space(seed=1234):
    cs = ConfigurationSpace(seed=seed)
    cs.add_hyperparameter(Ordinal("s", (1, 2, 4, 8, 16), default=1))
    return cs


# ---------------------------------------------------------------------------
# q=1 determinism: the batched engine must reproduce the legacy serial loop
# ---------------------------------------------------------------------------


def _legacy_serial(learner, seed, max_evals, warm=None):
    """The pre-engine run_search loop, inlined verbatim: the reference the
    q=1 Campaign must match config-for-config at a fixed seed."""
    search = BayesianSearch(small_space(), learner=learner, seed=seed)
    db = search.db
    evaluated = []
    for cfg in warm or []:
        if len(db) >= max_evals:
            break
        if db.contains(cfg):
            continue
        evaluated.append(dict(cfg))
        search.tell(cfg, evaluator(cfg))
    while len(db) < max_evals:
        cfg = search.ask()
        if not search.dedups_against_db and db.contains(cfg):
            search.tell_skipped(cfg)
        else:
            evaluated.append(dict(cfg))
            search.tell(cfg, evaluator(cfg))
    return evaluated, _records(db)


@pytest.mark.parametrize("learner", ["RF", "GP"])
@pytest.mark.parametrize("seed", [0, 7])
def test_q1_matches_legacy_serial_trajectory(learner, seed):
    warm = [small_space().default_configuration()]
    ref_evals, ref_records = _legacy_serial(learner, seed, 20, warm=warm)

    got_evals = []

    def spy(cfg):
        got_evals.append(dict(cfg))
        return evaluator(cfg)

    res = Campaign(small_space(), spy, max_evals=20, learner=learner,
                   seed=seed, parallel=1, warm_start=warm).run()
    # same configs, same order — both the evaluation sequence and the full
    # record stream (including GP duplicate-skips) are identical
    assert got_evals == ref_evals
    assert _records(res.db) == ref_records


def test_gp_parallel_duplicates_still_consume_budget():
    cs = ConfigurationSpace(seed=0)
    cs.add_hyperparameters([Categorical("a", (0, 1)), Categorical("b", (0, 1))])
    res = Campaign(cs, lambda c: EvalResult(float(c["a"] + c["b"]), True, {}),
                   max_evals=30, learner="GP", seed=0, n_initial=4,
                   parallel=4).run()
    assert len(res.db) == 30
    assert res.n_skipped >= 20
    assert any(r.status == SKIPPED_DUPLICATE for r in res.db.records)


def test_gp_parallel_never_skips_unmeasured_configs():
    """A GP proposal duplicating an *in-flight* (unmeasured) config must be
    deferred, not skipped: skipping would write a NaN objective as the
    config's canonical lookup entry and erase its constant-liar row."""
    cs = ConfigurationSpace(seed=0)
    cs.add_hyperparameter(Categorical("x", (0, 1)))

    def slow(c):
        time.sleep(0.05)
        return EvalResult(float(c["x"]), True, {})

    res = Campaign(cs, slow, max_evals=8, learner="GP", seed=0,
                   n_initial=2, parallel=4).run()
    assert len(res.db) == 8
    for r in res.db.records:
        if r.status == SKIPPED_DUPLICATE:
            # every skip points at a real, already-measured record
            assert r.info["duplicate_of"] is not None
            assert np.isfinite(r.objective)
    # the canonical lookup entry per config is a measured one
    for cfg in ({"x": 0}, {"x": 1}):
        rec = res.db.lookup(cfg)
        assert rec is not None and rec.status != SKIPPED_DUPLICATE


# ---------------------------------------------------------------------------
# parallel execution: exact budget, distinct in-flight candidates, speedup
# ---------------------------------------------------------------------------


def _timed_campaign(parallel, sleep_sec=0.25, max_evals=12):
    calls = []
    lock = threading.Lock()

    def padded(cfg):
        with lock:
            calls.append(dict(cfg))
        time.sleep(sleep_sec)
        return EvalResult(objective(cfg), True, {})

    t0 = time.perf_counter()
    res = Campaign(small_space(), padded, max_evals=max_evals, learner="RF",
                   seed=3, n_initial=4, parallel=parallel).run()
    return time.perf_counter() - t0, res, calls


def test_parallel_campaign_budget_and_speedup():
    wall_serial, res_s, calls_s = _timed_campaign(parallel=1)
    wall_par, res_p, calls_p = _timed_campaign(parallel=4)
    # exact budget at any width; RF never evaluates a config twice
    for res, calls in ((res_s, calls_s), (res_p, calls_p)):
        assert len(res.db) == 12 and len(calls) == 12
        keys = [tuple(sorted(c.items())) for c in calls]
        assert len(set(keys)) == len(keys)
    # the acceptance bar: >= 2x wall-clock at --parallel 4, equal max_evals
    assert wall_par * 2.0 <= wall_serial, (wall_serial, wall_par)
    # constant-liar batching still finds a competitive optimum
    assert res_p.best.objective <= res_s.best.objective * 1.5


def test_external_executor_is_not_shut_down():
    ex = ThreadExecutor(evaluator, max_workers=2)
    try:
        res = Campaign(small_space(), executor=ex, max_evals=8, seed=1).run()
        assert len(res.db) == 8
        # still usable: the campaign must not have shut the pool down
        assert ex.submit(small_space().default_configuration()).result().ok
    finally:
        ex.shutdown()


def test_inline_executor_propagates_exceptions():
    def boom(cfg):
        raise RuntimeError("evaluator crash")

    with pytest.raises(RuntimeError, match="evaluator crash"):
        Campaign(small_space(), boom, max_evals=4, seed=0).run()


# ---------------------------------------------------------------------------
# crash-safe resume: killed after k evals -> exactly max_evals - k more
# ---------------------------------------------------------------------------


def test_campaign_resumes_from_jsonl_checkpoint(tmp_path):
    db_path = str(tmp_path / "camp")
    k, total = 7, 18

    class Killed(BaseException):
        pass

    first_run = []

    def dying(cfg):
        if len(first_run) >= k:
            raise Killed()  # simulates the host dying mid-campaign
        first_run.append(dict(cfg))
        return evaluator(cfg)

    with pytest.raises(Killed):
        Campaign(small_space(), dying, max_evals=total, seed=5,
                 db_path=db_path).run()
    assert len(first_run) == k

    second_run = []

    def counting(cfg):
        second_run.append(dict(cfg))
        return evaluator(cfg)

    resumed = Campaign(small_space(), counting, max_evals=total, seed=5,
                       db_path=db_path)
    assert resumed.remaining == total - k  # budget accounting is exact
    res = resumed.run()
    assert len(second_run) == total - k
    assert len(res.db) == total
    # no config re-evaluated across the kill/resume boundary
    seen_before = {tuple(sorted(c.items())) for c in first_run}
    seen_after = {tuple(sorted(c.items())) for c in second_run}
    assert not (seen_before & seen_after)


def test_resume_timings_accumulate_post_checkpoint(tmp_path):
    """SearchResult.timings on a resumed campaign covers the post-resume
    epoch: a fresh Campaign builds a fresh timings dict, so the resumed run
    reports its own ask/tell counts from the checkpoint forward — not zeros,
    and not a double-count of the first run's work."""
    db_path = str(tmp_path / "camp")
    first = Campaign(small_space(), evaluator, max_evals=6, seed=3,
                     db_path=db_path).run()
    assert first.timings["n_tells"] == 6

    resumed = Campaign(small_space(), evaluator, max_evals=12, seed=3,
                       db_path=db_path)
    assert resumed.remaining == 6
    res = resumed.run()
    assert len(res.db) == 12
    # exactly the 6 post-resume evaluations were told this epoch
    assert res.timings["n_tells"] == 6
    assert res.timings["n_asks"] > 0
    assert res.timings["ask_sec"] > 0.0
    assert res.timings["tell_sec"] > 0.0


def test_parallel_resume_exact_budget(tmp_path):
    db_path = str(tmp_path / "camp")
    Campaign(small_space(), evaluator, max_evals=9, seed=2, db_path=db_path,
             parallel=3).run()
    calls = []

    def counting(cfg):
        calls.append(dict(cfg))
        return evaluator(cfg)

    res = Campaign(small_space(), counting, max_evals=21, seed=2,
                   db_path=db_path, parallel=3).run()
    assert len(res.db) == 21 and len(calls) == 12


# ---------------------------------------------------------------------------
# store concurrency: >= 4 executor threads publishing at once
# ---------------------------------------------------------------------------


def test_concurrent_store_put_from_executor_threads(tmp_path):
    from repro.dispatch import TuningRecord, TuningStore

    path = str(tmp_path / "store")
    store = TuningStore(path)
    n_threads, n_puts = 6, 25
    errors = []

    def hammer(tid):
        try:
            for i in range(n_puts):
                store.put(TuningRecord(
                    "k", ((64,),), "host",
                    {"s": tid * n_puts + i}, 1.0 / (1 + tid * n_puts + i)))
                store.put(TuningRecord(  # per-thread key, monotone improving
                    f"k{tid}", ((64,),), "host", {"s": i}, float(n_puts - i)))
        except BaseException as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=hammer, args=(t,)) for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert errors == []
    # a fresh reader folds the log to the true global best per key
    fresh = TuningStore(path)
    best = fresh.get("k", ((64,),), "host")
    assert best is not None
    assert best.objective == pytest.approx(1.0 / (n_threads * n_puts))
    for tid in range(n_threads):
        rec = fresh.get(f"k{tid}", ((64,),), "host")
        assert rec is not None and rec.config == {"s": n_puts - 1}


# ---------------------------------------------------------------------------
# drain: one deadline shared across futures
# ---------------------------------------------------------------------------


def test_drain_timeout_is_a_shared_deadline(tmp_path):
    from repro.dispatch import BackgroundTuner, TuningStore

    cs = ConfigurationSpace(seed=0)
    cs.add_hyperparameter(Ordinal("s", (1, 2, 4, 8), default=1))

    def slow(cfg):
        time.sleep(0.1)
        return EvalResult(1.0 / cfg["s"], True, {})

    store = TuningStore(str(tmp_path / "s"))
    tuner = BackgroundTuner(store, max_workers=1, max_evals=3, n_initial=1)
    try:
        # three ~0.3s campaigns on one worker run back-to-back (~0.9s total);
        # a 0.35s drain must give up at ~0.35s — per-future timeouts would
        # stretch to ~0.9s without ever raising
        for i, dims in enumerate([((4,),), ((8,),), ((16,),)]):
            tuner.submit("k", dims, "host", space=cs, evaluator=slow)
        t0 = time.perf_counter()
        with pytest.raises(TimeoutError):
            tuner.drain(timeout=0.35)
        assert time.perf_counter() - t0 < 0.7
        tuner.drain()  # no deadline: everything finishes cleanly
        assert tuner.errors == []
    finally:
        tuner.shutdown()


def test_submit_after_shutdown_degrades_not_crashes(tmp_path):
    from repro.dispatch import BackgroundTuner, TuningStore

    store = TuningStore(str(tmp_path / "s"))
    tuner = BackgroundTuner(store, max_workers=1)
    tuner.shutdown()
    # a serving-path miss enqueued against a shut-down tuner must be a no-op
    assert tuner.submit("k", ((4,),), "host", space=small_space(),
                        evaluator=evaluator) is None
    assert tuner.drain() == []


# ---------------------------------------------------------------------------
# serve-step hot swap: jit_cached entries rebuild on invalidate()
# ---------------------------------------------------------------------------


def test_invalidate_rebuilds_jit_cached_serve_step(tmp_path):
    from repro.dispatch import DispatchService, TuningRecord, TuningStore, register

    register("engine_toy_scale", builder=lambda cfg: lambda x: x * cfg["s"],
             space=lambda target="host", seed=1234: _scale_space(seed))
    store = TuningStore(str(tmp_path / "s"))
    store.put(TuningRecord("engine_toy_scale", ((4,),), "host", {"s": 2}, 0.5))
    svc = DispatchService(store)
    x = np.arange(4.0)

    def step(v):  # a serve step: dispatch resolves at trace time
        return svc.dispatch("engine_toy_scale", v)(v)

    serve = svc.jit_cached("serve_step/toy", step)
    np.testing.assert_array_equal(np.asarray(serve(x)), x * 2)
    # a background campaign publishes a better config and hot-swaps it in
    store.put(TuningRecord("engine_toy_scale", ((4,),), "host", {"s": 8}, 0.1))
    svc.invalidate("engine_toy_scale", ((4,),))
    # the held reference re-traces and bakes the new config in
    np.testing.assert_array_equal(np.asarray(serve(x)), x * 8)
    assert svc.stats["serve_rebuilt"] == 1
    # repeated calls reuse the rebuilt executable (no re-trace per call)
    np.testing.assert_array_equal(np.asarray(serve(x)), x * 8)
    assert svc.stats["serve_rebuilt"] == 1


def test_jit_cached_proxy_is_stable_across_invalidate():
    from repro.dispatch import DispatchService

    svc = DispatchService()
    f1 = svc.jit_cached("serve/m", lambda x: x + 1)
    svc.invalidate()
    f2 = svc.jit_cached("serve/m", lambda x: x + 1)
    assert f1 is f2


# ---------------------------------------------------------------------------
# the roofline cost backend (VariantSpec.make_evaluator)
# ---------------------------------------------------------------------------


def test_evaluator_for_spec_prefers_make_evaluator():
    from repro.dispatch.registry import VariantSpec

    marker = lambda cfg: EvalResult(0.123, True, {})  # noqa: E731
    spec = VariantSpec(name="x", builder=lambda cfg: (lambda: None),
                       space=lambda target: small_space(),
                       make_evaluator=lambda factory: marker)
    assert evaluator_for_spec(spec, lambda cfg: (None, ())) is marker


def test_dims_from_signature_roundtrip():
    from repro.kernels.problems import LARGE_SHAPES, dims_from_signature
    from repro.kernels.ref import problem_signature

    for name, dims in LARGE_SHAPES.items():
        sig = problem_signature(name, *dims)
        assert dims_from_signature(name, sig) == tuple(dims), name


def test_cost_evaluator_scores_with_kernel_cost():
    from repro.kernels.cost import kernel_cost
    from repro.kernels.problems import make_cost_evaluator

    cfg = dict(bm=128, bn=128, bk=128, pack=True)
    res = make_cost_evaluator("matmul", (256, 192, 224))(cfg)
    t, _ = kernel_cost("matmul", cfg, 256, 192, 224)
    assert res.ok and res.objective == pytest.approx(t)
    # infeasible (VMEM-blowing) config -> failed with penalty semantics
    bad = make_cost_evaluator("matmul", (4096, 4096, 4096))(
        dict(bm=1024, bn=1024, bk=2048, pack=True))
    assert not bad.ok


def test_cost_backend_background_tuning(tmp_path):
    """The ROADMAP item end-to-end: a background tuner attached to a
    cost-backend service tunes analytically — no TPU, no wall-clocking."""
    from repro.dispatch import BackgroundTuner, DispatchService, TuningStore
    from repro.dispatch import registry as registry_mod
    from repro.kernels.problems import register_cost_backend

    saved = dict(registry_mod._REGISTRY)
    try:
        register_cost_backend()
        store = TuningStore(str(tmp_path / "s"))
        tuner = BackgroundTuner(store, max_workers=1, max_evals=6, n_initial=2)
        svc = DispatchService(store, backend="cost", target="tpu",
                              tuner=tuner, jit=False)
        try:
            A = np.zeros((256, 192), np.float32)
            B = np.zeros((192, 224), np.float32)
            svc.dispatch("matmul", A, B)  # miss -> enqueue a cost campaign
            assert svc.stats["bg_enqueued"] == 1
            tuner.drain()
            assert tuner.errors == []
            recs = store.records(kernel="matmul", backend="cost")
            assert recs and recs[0].source == "background"
            assert np.isfinite(recs[0].objective)
        finally:
            tuner.shutdown()
    finally:
        registry_mod._REGISTRY.clear()
        registry_mod._REGISTRY.update(saved)


# ---------------------------------------------------------------------------
# engine plumbing details
# ---------------------------------------------------------------------------


def test_run_search_parallel_passthrough():
    res = run_search(small_space(), evaluator, max_evals=10, learner="RF",
                     seed=4, parallel=3)
    assert len(res.db) == 10 and res.n_evaluated == 10


def test_campaign_requires_evaluator_or_executor():
    with pytest.raises(ValueError):
        Campaign(small_space())


def test_failed_evaluations_counted_at_any_width():
    def flaky(cfg):
        if bool(cfg["pack"]):
            return EvalResult(1e9, False, {"error": "synthetic"})
        return evaluator(cfg)

    res = Campaign(small_space(), flaky, max_evals=20, seed=2, parallel=4).run()
    assert len(res.db) == 20
    assert res.n_failed == sum(1 for r in res.db.records if r.status == FAILED)
    assert res.n_failed > 0
    assert res.best is not None and not bool(res.best.config["pack"])
    assert res.n_evaluated == sum(1 for r in res.db.records if r.status == OK)
