"""End-to-end behaviour tests for the paper's system: a full autotuning
campaign over a real kernel (host backend), checkpointed training with
restart, and the dry-run cell machinery on a small mesh."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core import TimingEvaluator, autotune, find_min
from repro.core.database import PerformanceDatabase
from repro.data import SyntheticLM, make_batch
from repro.kernels import ref as R
from repro.kernels import variants as V
from repro.kernels.spaces import kernel_space
from repro.models import init_params
from repro.train import init_train_state, make_train_step


def test_full_campaign_on_syr2k_host():
    """The paper's core loop end to end: BO over the syr2k pragma space with
    measured wall-clock; the tuned config must be at least as fast as the
    space's default, and findMin must agree with the search result."""
    C, A, B = R.init_syr2k(128, 96)
    factory = V.syr2k_host((C, A, B))
    ev = TimingEvaluator(factory, repeats=2, warmup=1)
    space = kernel_space("syr2k", target="host")

    default_cfg = space.default_configuration()
    t_default = ev(default_cfg).objective

    res = autotune(space, ev, max_evals=18, learner="RF", seed=1234)
    assert res.best is not None
    assert res.best.objective <= t_default * 1.25  # noise headroom
    assert find_min(res.db).index == res.best.index
    # the tuned variant is numerically correct
    fn, args = factory(res.best.config)
    np.testing.assert_allclose(np.asarray(jax.jit(fn)(*args)),
                               np.asarray(R.syr2k_ref(C, A, B)),
                               atol=5e-3, rtol=5e-3)


def test_campaign_database_files(tmp_path):
    C, A, B = R.init_syr2k(64, 48)
    ev = TimingEvaluator(V.syr2k_host((C, A, B)), repeats=1, warmup=0)
    db_path = str(tmp_path / "camp")
    autotune(kernel_space("syr2k", target="host"), ev, max_evals=6,
             learner="ET", seed=0, db_path=db_path)
    assert os.path.exists(os.path.join(db_path, "results.csv"))
    assert os.path.exists(os.path.join(db_path, "results.jsonl"))
    db = PerformanceDatabase(db_path)
    assert len(db) == 6


def test_train_checkpoint_restart(tmp_path):
    """Fault-tolerance path: train, checkpoint, 'crash', restore, continue —
    losses after restart continue from the restored state."""
    from repro.ckpt import restore, save

    cfg = dataclasses.replace(get_reduced("qwen1.5-0.5b"), dtype=jnp.float32)
    params = init_params(cfg, jax.random.PRNGKey(0))
    opt = init_train_state(params)
    step = jax.jit(make_train_step(cfg, lr=1e-3))
    stream = SyntheticLM(cfg.vocab_size, 16, 4, seed=0)

    for i in range(4):
        params, opt, m = step(params, opt, make_batch(stream, i))
    save(str(tmp_path), {"params": params, "opt": opt}, step=4)

    # continue two more steps (ground truth)
    p_t, o_t = params, opt
    for i in (4, 5):
        p_t, o_t, m_t = step(p_t, o_t, make_batch(stream, i))

    # "crash": restore from checkpoint and replay the same two steps
    state, s = restore(str(tmp_path), {"params": params, "opt": opt})
    assert s == 4
    p_r, o_r = state["params"], state["opt"]
    for i in (4, 5):
        p_r, o_r, m_r = step(p_r, o_r, make_batch(stream, i))
    np.testing.assert_allclose(float(m_t["loss"]), float(m_r["loss"]), rtol=1e-5)


def test_dryrun_cell_on_tiny_mesh():
    """The dry-run machinery end to end on the devices we actually have."""
    from jax.sharding import Mesh
    devs = jax.devices()
    mesh = Mesh(np.array(devs[:1]).reshape(1, 1), ("data", "model"))
    from repro.launch.cells import lower_cell, plan_cell
    from repro.perf.roofline import analyze_compiled

    plan = plan_cell("qwen1.5-0.5b", "train_4k", mesh,
                     knobs={"accum": 1, "remat": "none"})
    lowered, aux = lower_cell(plan, mesh)
    compiled = lowered.compile()
    rep = analyze_compiled(compiled, chips=1, model_flops=aux["model_flops"])
    assert rep.flops_per_device > 0
    assert rep.dominant in ("compute", "memory", "collective")
    assert aux["model_flops"] > 0
