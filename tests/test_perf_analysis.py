"""HLO cost walker + roofline math: validated against closed forms."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.perf.hlo import parse_collectives, shape_bytes
from repro.perf.hlo_cost import module_cost, parse_module
from repro.perf.roofline import HW, RooflineReport, analyze_compiled


def _compiled(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_shape_bytes():
    assert shape_bytes("f32[8,4]{1,0}") == 128
    assert shape_bytes("bf16[10]") == 20
    assert shape_bytes("(f32[2,2], s8[4])") == 20
    assert shape_bytes("pred[]") == 1


def test_matmul_flops_closed_form():
    M, K, N = 64, 96, 32
    a = jnp.ones((M, K))
    b = jnp.ones((K, N))
    c = _compiled(lambda x, y: x @ y, a, b)
    cost = module_cost(c.as_text())
    want = 2 * M * N * K
    assert want <= cost.flops <= 1.2 * want, (cost.flops, want)


def test_scan_multiplies_by_trip_count():
    """The reason the walker exists: lax.scan bodies count x trips."""
    M = 32
    a = jnp.ones((M, M))

    def step(x, _):
        return x @ a, None

    def once(x):
        return (x @ a), None

    def scanned(x):
        out, _ = jax.lax.scan(step, x, None, length=10)
        return out

    c1 = _compiled(lambda x: once(x)[0], a)
    c10 = _compiled(scanned, a)
    f1 = module_cost(c1.as_text()).flops
    f10 = module_cost(c10.as_text()).flops
    assert 8 <= f10 / f1 <= 12, (f1, f10)


def test_elementwise_and_reduce_counted():
    x = jnp.ones((128, 128))
    c = _compiled(lambda v: jnp.exp(v).sum(), x)
    cost = module_cost(c.as_text())
    # exp: 128*128 flops, reduce: ~128*128
    assert cost.flops >= 128 * 128
    assert cost.bytes >= 128 * 128 * 4  # at least reads the input once


def test_parse_module_finds_entry():
    c = _compiled(lambda v: v + 1.0, jnp.ones((4,)))
    comps = parse_module(c.as_text())
    assert comps["__entry__"] is not None


def test_parse_collectives_counts_kinds():
    txt = """
  %ag = f32[16,128]{1,0} all-gather(%x), replica_groups={}, dimensions={0}
  %ar.1 = bf16[1024]{0} all-reduce(%y), to_apply=%sum
  %done = f32[8] all-reduce-done(%start)
"""
    stats = parse_collectives(txt)
    assert stats.count_by_kind["all-gather"] == 1
    assert stats.count_by_kind["all-reduce"] == 1
    assert stats.bytes_by_kind["all-gather"] == 16 * 128 * 4
    assert stats.bytes_by_kind["all-reduce"] == 1024 * 2


def test_roofline_terms_and_dominant():
    rep = RooflineReport(
        chips=256,
        flops_per_device=197e12,        # exactly 1 second of compute
        bytes_per_device=819e9 / 2.0,   # 0.5 s of HBM
        collective_bytes_per_device=50e9 / 4.0,  # 0.25 s of ICI
        collectives=None,
        peak_memory_per_device=None,
    )
    assert rep.compute_sec == pytest.approx(1.0)
    assert rep.memory_sec == pytest.approx(0.5)
    assert rep.collective_sec == pytest.approx(0.25)
    assert rep.dominant == "compute"
    assert rep.roofline_fraction == pytest.approx(1.0)
    assert rep.bound_sec == pytest.approx(1.0)


def test_roofline_fraction_under_memory_bound():
    rep = RooflineReport(
        chips=1, flops_per_device=197e12 * 0.1, bytes_per_device=819e9,
        collective_bytes_per_device=0.0, collectives=None,
        peak_memory_per_device=None, model_flops=197e12 * 0.05,
    )
    assert rep.dominant == "memory"
    assert rep.roofline_fraction == pytest.approx(0.1)
    assert rep.useful_flops_ratio == pytest.approx(0.5)


def test_analyze_compiled_end_to_end():
    a = jnp.ones((256, 256))
    c = _compiled(lambda x: (x @ x).sum(), a)
    rep = analyze_compiled(c, chips=1, model_flops=2 * 256**3)
    assert rep.flops_per_device > 0
    assert rep.useful_flops_ratio is not None
    assert 0.5 <= rep.useful_flops_ratio <= 1.2


def test_kernel_cost_model_sanity():
    from repro.kernels.cost import kernel_cost

    # infeasible when tiles exceed VMEM
    t, info = kernel_cost("syr2k", dict(bi=4096, bj=4096, bk=4096), 8192, 8192)
    assert not np.isfinite(t) and info["infeasible"] == "vmem"
    # aligned tiles beat badly aligned ones
    t_good, _ = kernel_cost("syr2k", dict(bi=256, bj=256, bk=256), 1200, 1000)
    t_bad, _ = kernel_cost("syr2k", dict(bi=96, bj=96, bk=96), 1200, 1000)
    assert np.isfinite(t_good) and t_good <= t_bad
    # fused temporal blocking halves heat3d HBM traffic
    t1, i1 = kernel_cost("heat3d", dict(bi=8, fuse_t=1), 120, 500)
    t2, i2 = kernel_cost("heat3d", dict(bi=8, fuse_t=2), 120, 500)
    assert i2["hbm_bytes"] < i1["hbm_bytes"]


def test_nested_scan_trip_products():
    """Nested lax.scan loops must multiply: outer(4) x inner(5) = 20x."""
    a = jnp.ones((16, 16))

    def inner_step(x, _):
        return x @ a, None

    def outer_step(x, _):
        y, _ = jax.lax.scan(inner_step, x, None, length=5)
        return y, None

    def nested(x):
        out, _ = jax.lax.scan(outer_step, x, None, length=4)
        return out

    c1 = _compiled(lambda x: x @ a, a)
    c20 = _compiled(nested, a)
    f1 = module_cost(c1.as_text()).flops
    f20 = module_cost(c20.as_text()).flops
    assert 16 <= f20 / f1 <= 24, (f1, f20)


def test_seq_parallel_knob_lowers_and_reduces_activation_bytes():
    """The §Perf headline knob: sequence-parallel residual stream must lower
    on a (data, model) mesh and not increase the walker's memory bytes."""
    import numpy as np
    from jax.sharding import Mesh

    devs = jax.devices()
    mesh = Mesh(np.array(devs[:1]).reshape(1, 1), ("data", "model"))
    from repro.launch.cells import lower_cell, plan_cell

    outs = {}
    for sp in (False, True):
        plan = plan_cell("qwen1.5-0.5b", "train_4k", mesh,
                         knobs={"accum": 1, "remat": "none", "seq_parallel": sp})
        lowered, _ = lower_cell(plan, mesh)
        outs[sp] = module_cost(lowered.compile().as_text())
    # on a 1x1 mesh SP is a no-op: identical (or near-identical) cost
    assert abs(outs[True].flops - outs[False].flops) / outs[False].flops < 0.05
