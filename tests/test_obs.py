"""repro.obs: lock-free sharded metrics folding to exact totals under
concurrency, deterministic (associative + commutative) histogram merges,
Prometheus text exposition, crash-tolerant Chrome-trace JSONL, the HTTP
scrape endpoint, and the instrumentation hooks in dispatch / engine / fleet."""

import json
import math
import os
import threading
import urllib.request

import numpy as np
import pytest

from repro.obs.export import (
    ObsServer,
    prometheus_text,
    read_snapshot_file,
    write_snapshot,
)
from repro.obs.metrics import (
    BUCKET_BOUNDS,
    Histogram,
    MetricsRegistry,
    get_registry,
    histogram_quantile,
    merge_snapshots,
    set_registry,
    summarize_histograms,
)
from repro.obs.trace import (
    Tracer,
    configure_tracer,
    export_chrome_trace,
    get_tracer,
    validate_trace,
)


@pytest.fixture
def fresh_registry():
    """Swap in an isolated default registry; restore the old one after."""
    old = get_registry()
    reg = set_registry(MetricsRegistry())
    try:
        yield reg
    finally:
        set_registry(old)


@pytest.fixture
def no_tracer():
    """Force the NULL tracer for the test, restoring state after."""
    configure_tracer(None)
    yield
    configure_tracer(None)


# ---------------------------------------------------------------------------
# metrics core
# ---------------------------------------------------------------------------


def test_counter_gauge_histogram_roundtrip():
    reg = MetricsRegistry()
    reg.add("requests_total", kernel="syr2k", path="fast_hit")
    reg.add("requests_total", 2.0, kernel="syr2k", path="fast_hit")
    reg.set_gauge("pending", 3, host="a")
    reg.set_gauge("pending", 7, host="a")   # last write wins
    reg.observe("latency_seconds", 0.001, kernel="syr2k")
    reg.observe("latency_seconds", 0.002, kernel="syr2k")
    snap = reg.snapshot()
    assert snap["schema"] == "repro.obs/1"
    assert snap["buckets"] == list(BUCKET_BOUNDS)
    (c,) = snap["counters"]
    assert c == {"name": "requests_total",
                 "labels": {"kernel": "syr2k", "path": "fast_hit"},
                 "value": 3.0}
    (g,) = snap["gauges"]
    assert g["value"] == 7.0
    (h,) = snap["histograms"]
    assert h["count"] == 2 and abs(h["sum"] - 0.003) < 1e-12
    assert sum(h["counts"]) == 2
    # snapshot round-trips through json unchanged
    assert json.loads(json.dumps(snap)) == snap


def test_concurrent_recording_folds_to_exact_totals():
    """>= 4 threads hammer one registry; after they quiesce, the folded
    snapshot must account for every single operation."""
    reg = MetricsRegistry()
    n_threads, n_ops = 6, 5000
    barrier = threading.Barrier(n_threads)

    def worker(tid):
        barrier.wait()
        for i in range(n_ops):
            reg.add("ops_total", thread="shared")
            reg.observe("lat_seconds", (i % 100 + 1) * 1e-6, thread="shared")

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    snap = reg.snapshot()
    (c,) = snap["counters"]
    assert c["value"] == float(n_threads * n_ops)
    (h,) = snap["histograms"]
    assert h["count"] == n_threads * n_ops
    assert sum(h["counts"]) == n_threads * n_ops
    expected_sum = n_threads * sum((i % 100 + 1) * 1e-6 for i in range(n_ops))
    assert abs(h["sum"] - expected_sum) < 1e-9


def test_histogram_merge_associative_commutative():
    """Any grouping and any order of merges yields the identical snapshot —
    the property that makes cross-host folding deterministic. Seeded-rng
    shuffle property test (same idiom as the fleet merge tests)."""
    rng = np.random.default_rng(42)

    def random_snapshot(seed):
        reg = MetricsRegistry()
        r = np.random.default_rng(seed)
        for _ in range(50):
            reg.add("c_total", float(r.integers(1, 5)),
                    k=str(r.integers(0, 3)))
            reg.observe("h_seconds", float(r.uniform(1e-6, 10.0)),
                        k=str(r.integers(0, 3)))
        return reg.snapshot()

    def assert_equivalent(a, b):
        # bucket counts (the quantile inputs) must be BIT-identical in any
        # merge order; float sums are only reassociated, so equal to ulps
        assert [(h["name"], h["labels"], h["counts"], h["count"])
                for h in a["histograms"]] \
            == [(h["name"], h["labels"], h["counts"], h["count"])
                for h in b["histograms"]]
        for ha, hb in zip(a["histograms"], b["histograms"]):
            assert math.isclose(ha["sum"], hb["sum"], rel_tol=1e-12)
        assert [(c["name"], c["labels"]) for c in a["counters"]] \
            == [(c["name"], c["labels"]) for c in b["counters"]]
        for ca, cb in zip(a["counters"], b["counters"]):
            assert math.isclose(ca["value"], cb["value"], rel_tol=1e-12)

    snaps = [random_snapshot(s) for s in range(6)]
    reference = merge_snapshots(*snaps)
    for _ in range(10):
        order = list(range(len(snaps)))
        rng.shuffle(order)
        shuffled = [snaps[i] for i in order]
        # commutative: any permutation merges to the same result
        assert_equivalent(merge_snapshots(*shuffled), reference)
        # associative: ((a+b)+c)+... == a+(b+(c+...)) — fold pairwise left
        # and right and compare
        left = shuffled[0]
        for s in shuffled[1:]:
            left = merge_snapshots(left, s)
        right = shuffled[-1]
        for s in reversed(shuffled[:-1]):
            right = merge_snapshots(s, right)
        assert_equivalent(left, right)
        assert_equivalent(left, reference)


def test_merge_rejects_bucket_schema_mismatch():
    reg = MetricsRegistry()
    reg.observe("h_seconds", 0.5)
    snap = reg.snapshot()
    alien = dict(snap, buckets=[0.1, 1.0, 10.0])
    with pytest.raises(ValueError, match="bucket schema"):
        merge_snapshots(snap, alien)


def test_histogram_quantiles():
    h = Histogram()
    for _ in range(100):
        h.observe(0.001)  # ~1ms
    assert 0.0005 < h.quantile(0.5) < 0.002
    assert 0.0005 < h.quantile(0.99) < 0.002
    # +Inf bucket clamps to the largest finite bound
    h2 = Histogram()
    h2.observe(1e9)
    assert h2.quantile(0.5) == BUCKET_BOUNDS[-1]
    # empty histogram -> NaN
    assert math.isnan(histogram_quantile([0] * (len(BUCKET_BOUNDS) + 1), 0.5))


def test_summarize_histograms_filters():
    reg = MetricsRegistry()
    reg.observe("dispatch_execute_seconds", 0.01, kernel="syr2k")
    reg.observe("fleet_pull_seconds", 0.02, host="a")
    snap = reg.snapshot()
    rows = summarize_histograms(snap, name="dispatch_execute_seconds")
    assert len(rows) == 1 and rows[0]["count"] == 1
    assert rows[0]["p50"] <= rows[0]["p99"]
    rows = summarize_histograms(snap, prefix="fleet_")
    assert [r["name"] for r in rows] == ["fleet_pull_seconds"]


# ---------------------------------------------------------------------------
# tracing
# ---------------------------------------------------------------------------


def test_trace_roundtrip_and_torn_tail(tmp_path, no_tracer):
    path = str(tmp_path / "trace.jsonl")
    tracer = Tracer(path, process_name="test-proc")
    with tracer.span("work.outer", kernel="syr2k"):
        with tracer.span("work.inner"):
            pass
    tracer.instant("marker", n=3)
    tracer.close()
    report = validate_trace(path)
    assert report["ok"]
    assert report["invalid"] == 0 and report["skipped"] == 0
    assert {"work.outer", "work.inner", "marker"} <= set(report["names"])
    # every X span carries microsecond ts + dur and pid/tid
    events = [json.loads(line) for line in open(path)]
    spans = [e for e in events if e["ph"] == "X"]
    assert len(spans) == 2
    for ev in spans:
        assert ev["dur"] >= 0 and ev["ts"] > 0 and ev["pid"] == os.getpid()
    # inner nested within outer on the timeline
    inner = next(e for e in spans if e["name"] == "work.inner")
    outer = next(e for e in spans if e["name"] == "work.outer")
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"]

    # a torn tail (killed writer) is skipped, not fatal — and a new Tracer
    # appending afterwards repairs it so its events stay line-delimited
    with open(path, "a") as f:
        f.write('{"name": "torn", "ph": "X", "ts": 1')
    report = validate_trace(path)
    assert report["ok"] and report["skipped"] == 1
    tracer2 = Tracer(path)
    with tracer2.span("after.crash"):
        pass
    tracer2.close()
    report = validate_trace(path)
    assert report["ok"] and "after.crash" in report["names"]


def test_trace_error_span_and_missing_file(tmp_path, no_tracer):
    path = str(tmp_path / "t.jsonl")
    tracer = Tracer(path)
    with pytest.raises(RuntimeError):
        with tracer.span("boom"):
            raise RuntimeError("x")
    tracer.close()
    (ev,) = [json.loads(line) for line in open(path)]
    assert ev["args"]["error"] == "RuntimeError"
    assert not validate_trace(str(tmp_path / "absent.jsonl"))["ok"]


def test_export_chrome_trace_is_loadable_json(tmp_path, no_tracer):
    src = str(tmp_path / "trace.jsonl")
    out = str(tmp_path / "trace.chrome.json")
    tracer = Tracer(src)
    with tracer.span("a"):
        pass
    tracer.close()
    n = export_chrome_trace(src, out)
    assert n == 1
    doc = json.load(open(out))
    assert doc["traceEvents"][0]["name"] == "a"


def test_env_var_activates_tracer(tmp_path, no_tracer, monkeypatch):
    path = str(tmp_path / "env.jsonl")
    monkeypatch.setenv("REPRO_TRACE", path)
    # reset the lazy singleton so the env var is consulted
    import repro.obs.trace as trace_mod
    trace_mod._tracer = None
    t = get_tracer()
    assert t.enabled and t.path == path
    with t.span("via.env"):
        pass
    configure_tracer(None)
    assert "via.env" in validate_trace(path)["names"]


# ---------------------------------------------------------------------------
# export: snapshots, Prometheus text, HTTP scrape
# ---------------------------------------------------------------------------


def test_snapshot_file_write_read_merge(tmp_path):
    path = str(tmp_path / "obs.jsonl")
    r1, r2 = MetricsRegistry(), MetricsRegistry()
    r1.add("c_total", 2.0, host="a")
    r2.add("c_total", 3.0, host="a")
    r1.observe("h_seconds", 0.01)
    r2.observe("h_seconds", 0.02)
    write_snapshot(path, registry=r1, source="test")
    write_snapshot(path, registry=r2, source="test")
    lines = read_snapshot_file(path, merge=False)
    assert len(lines) == 2 and all(line["source"] == "test" for line in lines)
    merged = read_snapshot_file(path)
    (c,) = merged["counters"]
    assert c["value"] == 5.0
    (h,) = merged["histograms"]
    assert h["count"] == 2


def test_prometheus_text_format():
    reg = MetricsRegistry()
    reg.add("requests_total", 4, kernel="syr2k", path="fast_hit")
    reg.set_gauge("pending", 2, host='we"ird')
    reg.observe("execute_seconds", 0.001, kernel="syr2k")
    text = prometheus_text(registry=reg)
    assert '# TYPE repro_requests_total counter' in text
    assert 'repro_requests_total{kernel="syr2k",path="fast_hit"} 4' in text
    assert '# TYPE repro_execute_seconds histogram' in text
    assert 'repro_execute_seconds_count{kernel="syr2k"} 1' in text
    assert 'le="+Inf"' in text
    assert '\\"' in text  # label values escaped
    # _bucket series are cumulative and end at the total count
    bucket_lines = [ln for ln in text.splitlines()
                    if ln.startswith("repro_execute_seconds_bucket")]
    assert len(bucket_lines) == len(BUCKET_BOUNDS) + 1
    assert bucket_lines[-1].endswith(" 1")
    cums = [int(ln.rsplit(" ", 1)[1]) for ln in bucket_lines]
    assert cums == sorted(cums)


def test_obs_server_scrape():
    reg = MetricsRegistry()
    reg.observe("execute_seconds", 0.005, kernel="syr2k")
    server = ObsServer(registry=reg).start()
    try:
        with urllib.request.urlopen(server.url + "/metrics") as r:
            text = r.read().decode()
        assert "repro_execute_seconds_count" in text
        with urllib.request.urlopen(server.url + "/snapshot") as r:
            snap = json.loads(r.read())
        assert snap["schema"] == "repro.obs/1"
        assert urllib.request.urlopen(server.url + "/nope").status == 404
    except urllib.error.HTTPError as e:
        assert e.code == 404  # the /nope probe
    finally:
        server.stop()


# ---------------------------------------------------------------------------
# integration: engine + fleet instrumentation
# ---------------------------------------------------------------------------


def test_campaign_records_metrics_and_spans(tmp_path, fresh_registry, no_tracer):
    from repro.core import EvalResult
    from repro.core.space import ConfigurationSpace, Ordinal
    from repro.engine import Campaign

    trace_path = str(tmp_path / "campaign.jsonl")
    configure_tracer(trace_path)
    cs = ConfigurationSpace(seed=1)
    cs.add_hyperparameter(Ordinal("s", (1, 2, 4, 8), default=1))
    res = Campaign(cs, lambda cfg: EvalResult(1.0 / cfg["s"], True, {}),
                   max_evals=4, n_initial=2, seed=1).run()
    configure_tracer(None)
    assert res.best is not None
    # timing dicts unchanged for existing consumers...
    assert res.timings["n_tells"] == 4 and res.timings["ask_sec"] >= 0.0
    # ...and the same phases landed in the registry as histograms
    rows = summarize_histograms(fresh_registry.snapshot(), prefix="campaign_")
    by_name = {r["name"]: r for r in rows}
    assert by_name["campaign_tell_seconds"]["count"] == 4
    assert by_name["campaign_evaluate_seconds"]["count"] == 4
    assert by_name["campaign_ask_seconds"]["count"] == res.timings["n_asks"]
    # ...and the trace timeline has every phase (+ the db-less campaign has
    # no checkpoint spans)
    report = validate_trace(trace_path)
    assert report["ok"]
    assert {"campaign.ask", "campaign.evaluate", "campaign.tell"} \
        <= set(report["names"])


def test_sync_agent_records_cycle_durations(tmp_path, fresh_registry):
    from repro.dispatch.store import TuningStore
    from repro.fleet import Replica, SyncAgent, transport_from_spec

    replica = Replica(TuningStore(str(tmp_path / "store")))
    transport = transport_from_spec("file:" + str(tmp_path / "shared"))
    agent = SyncAgent(replica, transport)
    out = agent.sync_once()
    agent.sync_once()
    # the return dict keeps its pre-obs shape (quiesce loops compare exactly)
    assert out == {"applied": 0, "published": 0, "pending": 0}
    assert agent.stats["cycles"] == 2
    for k in ("pull_sec", "merge_sec", "push_sec"):
        assert agent.stats[k] >= 0.0
    rows = {r["name"]: r for r in summarize_histograms(
        fresh_registry.snapshot(), prefix="fleet_")}
    for name in ("fleet_pull_seconds", "fleet_merge_seconds",
                 "fleet_push_seconds", "fleet_cycle_seconds"):
        assert rows[name]["count"] == 2, name
    # lag is only observable from the second cycle on (needs a prior sync)
    assert rows["fleet_replication_lag_seconds"]["count"] == 1
    # and the replica's status surfaces the same summaries
    status = replica.status(transport)
    assert {r["name"] for r in status["obs"]} == set(rows)


def test_fleet_server_metrics_route(tmp_path, fresh_registry):
    from repro.dispatch.store import TuningStore
    from repro.fleet import FleetServer, Replica
    from repro.fleet.http import HttpTransport

    fresh_registry.observe("fleet_pull_seconds", 0.01, host="me")
    replica = Replica(TuningStore(str(tmp_path / "store")))
    server = FleetServer(replica).start()
    try:
        with urllib.request.urlopen(server.url + "/metrics") as r:
            text = r.read().decode()
        assert "repro_fleet_pull_seconds_count" in text
        peer = HttpTransport(server.url).status()
        assert peer["host"] == replica.host_id and "obs" in peer
    finally:
        server.stop()
