"""repro.fleet: oplog emission + deterministic merge, file/HTTP transports,
the anti-entropy SyncAgent, and the acceptance contracts — host B serves
host A's tuned config with zero local evaluations, quarantined/evicted
records never resurrect, and re-applying any op stream is idempotent."""

import dataclasses
import random
import threading
import time

import numpy as np
import pytest

from repro.core.space import ConfigurationSpace, Ordinal
from repro.dispatch import (
    DispatchService,
    TuningRecord,
    TuningStore,
    register,
)
from repro.dispatch.lookup import warm_start_material
from repro.fleet import (
    FileTransport,
    MergeState,
    Op,
    OpLog,
    Replica,
    SyncAgent,
    transport_from_spec,
)


def _rec(kernel="k", dims=(64, 64), backend="host", obj=1.0, **cfg):
    return TuningRecord(kernel=kernel, signature=(tuple(dims),), backend=backend,
                        config=cfg or {"t": 8}, objective=obj)


def _host(tmp_path, name) -> tuple[TuningStore, Replica]:
    store = TuningStore(str(tmp_path / name / "store"))
    return store, Replica(store)


def _contents(store: TuningStore) -> dict:
    return {r.key(): (tuple(sorted(r.config.items())), r.objective)
            for r in store.records()}


def _quiesce(*agents, rounds=6):
    """Anti-entropy to a fixed point: a few alternating cycles with no
    traffic in either direction."""
    for _ in range(rounds):
        if all(a.sync_once() == {"applied": 0, "published": 0, "pending": 0}
               for a in agents):
            return
    raise AssertionError("fleet did not quiesce")


# ---------------------------------------------------------------------------
# ops + oplog
# ---------------------------------------------------------------------------


def test_op_json_roundtrip():
    op = Op(host="hA", seq=3, clock=17, kind="put", record=_rec(obj=0.5, t=4))
    back = Op.from_json(op.to_json())
    assert back == op
    assert back.stamp == (17, "hA", 3)


def test_oplog_emit_assigns_monotonic_seq_and_clock(tmp_path):
    log = OpLog(str(tmp_path / "fleet"))
    a = log.emit("put", _rec(obj=2.0))
    b = log.emit("put", _rec(obj=1.0))
    assert (a.seq, b.seq) == (1, 2)
    assert b.clock > a.clock
    assert log.version_vector() == {log.host_id: 2}


def test_oplog_replay_restores_state(tmp_path):
    path = str(tmp_path / "fleet")
    log = OpLog(path)
    log.emit("put", _rec(obj=2.0, t=8))
    log.emit("put", _rec(obj=1.0, t=16))
    fresh = OpLog(path)
    assert fresh.host_id == log.host_id
    assert fresh.version_vector() == log.version_vector()
    assert len(fresh) == 2
    win = fresh.state.winner(_rec().key())
    assert win.record.config == {"t": 16}


def test_oplog_ingest_is_idempotent(tmp_path):
    src = OpLog(str(tmp_path / "a"))
    src.emit("put", _rec(obj=1.0))
    dst = OpLog(str(tmp_path / "b"))
    ops = src.ops_after({})
    applied, changed = dst.ingest(ops)
    assert len(applied) == 1 and changed
    applied2, changed2 = dst.ingest(ops)
    assert applied2 == [] and not changed2
    assert len(dst) == 1


# ---------------------------------------------------------------------------
# merge semantics: deterministic under any order, quarantine/tombstone aware
# ---------------------------------------------------------------------------


def _winners(state: MergeState) -> dict:
    out = {}
    for key in state.keys():
        w = state.winner(key)
        if w is not None:
            out[key] = (tuple(sorted(w.record.config.items())),
                        w.record.objective, w.stamp)
    return out


def test_merge_lowest_objective_wins_per_key():
    s = MergeState()
    s.apply(Op("hA", 1, 1, "put", _rec(obj=0.8, t=2)))
    s.apply(Op("hB", 1, 2, "put", _rec(obj=0.3, t=4)))
    assert s.winner(_rec().key()).record.config == {"t": 4}


def test_merge_evict_tombstone_resurrects_newer_put_any_order():
    # the frontier case a winner-only fold gets wrong: p1 best but tombstoned,
    # p2 worse but newer than the tombstone -> p2 must win in EVERY order
    p1 = Op("hA", 1, 2, "put", _rec(obj=1.0, t=2))
    p2 = Op("hB", 1, 10, "put", _rec(obj=5.0, t=8))
    ev = Op("hA", 2, 3, "evict", _rec(obj=1.0, t=2))
    for order in ([p1, p2, ev], [p1, ev, p2], [ev, p1, p2],
                  [p2, p1, ev], [ev, p2, p1], [p2, ev, p1]):
        s = MergeState()
        for op in order:
            s.apply(op)
        w = s.winner(_rec().key())
        assert w is not None and w.record.config == {"t": 8}, order


def test_merge_quarantine_resurrects_runner_up_any_order():
    p1 = Op("hA", 1, 1, "put", _rec(obj=0.8, t=2))
    p2 = Op("hB", 1, 2, "put", _rec(obj=0.3, t=4))
    q = Op("hB", 2, 3, "quarantine", _rec(obj=0.3, t=4))
    for order in ([p1, p2, q], [q, p1, p2], [p2, q, p1]):
        s = MergeState()
        for op in order:
            s.apply(op)
        w = s.winner(_rec().key())
        assert w is not None and w.record.config == {"t": 2}, order
        # and the poisoned config stays dead even if re-put afterwards
        s.apply(Op("hC", 1, 9, "put", _rec(obj=0.01, t=4)))
        assert s.winner(_rec().key()).record.config == {"t": 2}


def test_merge_property_shuffled_streams_converge():
    """Property-style: a random op soup over 3 hosts and 4 keys folds to the
    same winners under 20 random application orders."""
    rng = random.Random(1234)
    ops = []
    for hi, host in enumerate(("hA", "hB", "hC")):
        clock = hi  # desynchronized clocks
        for seq in range(1, 13):
            clock += rng.randint(1, 3)
            dims = rng.choice(((8,), (16,), (32,), (64,)))
            kind = rng.choices(("put", "quarantine", "evict"),
                               weights=(6, 1, 1))[0]
            rec = _rec(dims=dims, obj=round(rng.uniform(0.1, 2.0), 3),
                       t=rng.choice((2, 4, 8)))
            ops.append(Op(host, seq, clock, kind, rec))
    reference = None
    for _ in range(20):
        rng.shuffle(ops)
        s = MergeState()
        for op in ops:
            s.apply(op)
        winners = _winners(s)
        if reference is None:
            reference = winners
        assert winners == reference
    assert reference  # the soup must leave at least one live winner


# ---------------------------------------------------------------------------
# file transport
# ---------------------------------------------------------------------------


def test_file_transport_push_is_idempotent_across_instances(tmp_path):
    log = OpLog(str(tmp_path / "fleet"))
    log.emit("put", _rec(obj=1.0))
    root = str(tmp_path / "shared")
    t1 = FileTransport(root)
    assert t1.push(log) == 1
    assert t1.push(log) == 0
    # a fresh transport (restarted host) re-derives the high-water mark
    assert FileTransport(root).push(log) == 0
    assert FileTransport(root).pending(log) == 0


def test_file_transport_pull_skips_torn_tail(tmp_path):
    a = OpLog(str(tmp_path / "a"))
    a.emit("put", _rec(obj=1.0))
    root = tmp_path / "shared"
    FileTransport(str(root)).push(a)
    with open(root / f"{a.host_id}.ops.jsonl", "a") as f:
        f.write('{"kernel": "k", "op": {"host"')  # crashed writer fragment
    b = OpLog(str(tmp_path / "b"))
    t = FileTransport(str(root))
    ops = t.pull(b)
    assert len(ops) == 1  # the complete line only; fragment left for later


def test_transport_from_spec(tmp_path):
    t = transport_from_spec(f"file:{tmp_path / 'x'}")
    assert isinstance(t, FileTransport)
    from repro.fleet import HttpTransport

    assert isinstance(transport_from_spec("http://127.0.0.1:1"), HttpTransport)
    with pytest.raises(ValueError):
        transport_from_spec("carrier-pigeon:coop")


# ---------------------------------------------------------------------------
# replica + sync: convergence
# ---------------------------------------------------------------------------


def test_two_hosts_converge_bidirectionally(tmp_path):
    sa, ra = _host(tmp_path, "a")
    sb, rb = _host(tmp_path, "b")
    shared = str(tmp_path / "shared")
    aa = SyncAgent(ra, FileTransport(shared))
    ab = SyncAgent(rb, FileTransport(shared))
    sa.put(_rec(dims=(8,), obj=0.5, t=2))          # A-only key
    sb.put(_rec(dims=(16,), obj=0.7, t=4))         # B-only key
    sa.put(_rec(dims=(32,), obj=0.9, t=2))         # contested key:
    sb.put(_rec(dims=(32,), obj=0.2, t=16))        #   B's is better
    _quiesce(aa, ab)
    assert _contents(sa) == _contents(sb)
    assert sa.get("k", ((32,),), "host").config == {"t": 16}
    assert len(sa) == 3


def test_host_b_serves_host_a_config_with_zero_local_evals(tmp_path):
    """The acceptance contract: after sync, host B's dispatch() resolves the
    config host A tuned — exact store hit, no campaign, no evaluation."""
    _toy_fleet_kernel()
    sa, ra = _host(tmp_path, "a")
    sb, rb = _host(tmp_path, "b")
    shared = str(tmp_path / "shared")
    sa.put(TuningRecord("fleet_scale", ((4,),), "host", {"s": 8}, 0.125,
                        n_evals=200, source="campaign:hostA"))
    SyncAgent(ra, FileTransport(shared)).sync_once()
    SyncAgent(rb, FileTransport(shared)).sync_once()

    svc = DispatchService(sb)                      # no tuner: cannot evaluate
    x = np.arange(4.0)
    out = np.asarray(svc.call("fleet_scale", x))
    np.testing.assert_array_equal(out, x * 8)
    assert svc.stats["store_exact"] == 1
    got = sb.get("fleet_scale", ((4,),), "host")
    assert got.source == "campaign:hostA" and got.n_evals == 200


def test_replayed_stream_is_idempotent_on_fresh_host(tmp_path):
    sa, ra = _host(tmp_path, "a")
    sa.put(_rec(dims=(8,), obj=0.5, t=2))
    sa.put(_rec(dims=(8,), obj=0.3, t=4))
    sa.quarantine(_rec(dims=(16,), obj=1.0, t=8))
    ops = ra.oplog.ops_after({})
    sc, rc = _host(tmp_path, "c")
    rc.ingest(ops)
    first = _contents(sc)
    assert rc.ingest(ops) == 0                     # second application: no-op
    assert _contents(sc) == first
    # and a store restart replays the log to the same view
    assert _contents(TuningStore(sc.path)) == first


# ---------------------------------------------------------------------------
# quarantine + compaction tombstones must propagate (and never resurrect)
# ---------------------------------------------------------------------------


def test_quarantine_propagates_and_bans_reintroduction(tmp_path):
    sa, ra = _host(tmp_path, "a")
    sb, rb = _host(tmp_path, "b")
    shared = str(tmp_path / "shared")
    aa, ab = SyncAgent(ra, FileTransport(shared)), SyncAgent(rb, FileTransport(shared))
    sa.put(_rec(dims=(8,), obj=0.5, t=2))
    _quiesce(aa, ab)
    assert sb.get("k", ((8,),), "host") is not None
    sa.quarantine(_rec(dims=(8,), obj=0.5, t=2))
    _quiesce(aa, ab)
    assert sb.get("k", ((8,),), "host") is None
    # B's store now refuses the poisoned config outright, like A's
    assert not sb.put(_rec(dims=(8,), obj=0.01, t=2))
    # ...but a different config for the key is welcome, and replicates
    assert sb.put(_rec(dims=(8,), obj=0.4, t=16))
    _quiesce(ab, aa)
    assert sa.get("k", ((8,),), "host").config == {"t": 16}


def test_compacted_eviction_does_not_resurrect_on_pull(tmp_path):
    """The satellite regression: compact -> sync -> the record stays gone,
    even though a peer still carries its original put op."""
    sa, ra = _host(tmp_path, "a")
    sb, rb = _host(tmp_path, "b")
    shared = str(tmp_path / "shared")
    aa, ab = SyncAgent(ra, FileTransport(shared)), SyncAgent(rb, FileTransport(shared))
    sa.put(dataclasses.replace(_rec(dims=(8,), obj=0.5, t=2),
                               created=time.time() - 3600))
    sa.put(_rec(dims=(16,), obj=0.7, t=4))
    _quiesce(aa, ab)
    assert len(sb) == 2
    assert sa.compact(ttl_sec=60) == 1             # evicts the stale key
    _quiesce(aa, ab)
    assert sb.get("k", ((8,),), "host") is None    # tombstone reached B
    _quiesce(ab, aa)                               # and B's put can't undo it
    assert sa.get("k", ((8,),), "host") is None
    assert TuningStore(sb.path).get("k", ((8,),), "host") is None  # replay too
    # a genuinely new result (stamped after the tombstone) resurrects the key
    assert sb.put(_rec(dims=(8,), obj=0.45, t=32))
    _quiesce(ab, aa)
    assert sa.get("k", ((8,),), "host").config == {"t": 32}


def test_offline_host_converges_after_evict_plus_same_config_reput(tmp_path):
    """A host that missed the eviction and ingests evict + re-put of the SAME
    config (at a worse, newer objective) in one batch must still converge:
    its stale lower-objective record is dead in the merge and gets evicted."""
    sa, ra = _host(tmp_path, "a")
    sb, rb = _host(tmp_path, "b")
    sc, rc = _host(tmp_path, "c")
    shared = str(tmp_path / "shared")
    aa = SyncAgent(ra, FileTransport(shared))
    ab = SyncAgent(rb, FileTransport(shared))
    ac = SyncAgent(rc, FileTransport(shared))
    sa.put(dataclasses.replace(_rec(dims=(8,), obj=3.0, t=2),
                               created=time.time() - 3600))
    _quiesce(aa, ab, ac)                           # everyone serves (t2, 3.0)
    # C goes offline; A evicts; B re-measures the same config, slower
    sa.compact(ttl_sec=60)
    _quiesce(aa, ab)
    assert sb.get("k", ((8,),), "host") is None
    sb.put(_rec(dims=(8,), obj=5.0, t=2))
    _quiesce(ab, aa)
    # C comes back and sees evict + new put in one pull
    _quiesce(ac, aa, ab)
    assert _contents(sc) == _contents(sa) == _contents(sb)
    assert sc.get("k", ((8,),), "host").objective == 5.0


def test_quarantine_survives_crash_between_ingest_and_store_apply(tmp_path):
    """vv-dedup delivers a quarantine op exactly once — if the process dies
    after the durable oplog append but before the store learns the ban, the
    next Replica over the same dirs must re-derive it from the merge."""
    sa, ra = _host(tmp_path, "a")
    sa.put(_rec(dims=(8,), obj=0.5, t=2))
    sa.quarantine(_rec(dims=(8,), obj=0.5, t=2))
    ops = ra.oplog.ops_after({})
    # "crashing" host B: the oplog ingests durably, reconcile never runs
    b_store = str(tmp_path / "b" / "store")
    TuningStore(b_store).put(_rec(dims=(8,), obj=0.9, t=2))  # the poisoned cfg
    OpLog(str(tmp_path / "b" / "store" / "fleet")).ingest(ops)
    # restart: Replica bootstrap reconciles oplog state into the store
    sb = TuningStore(b_store)
    Replica(sb)
    assert sb.get("k", ((8,),), "host") is None
    assert not sb.put(_rec(dims=(8,), obj=0.01, t=2))  # ban reached the store


def test_evict_survives_crash_between_ingest_and_store_apply(tmp_path):
    """The evict twin of the quarantine crash window: host B durably ingests
    A's tombstone but dies before the store applies it. The restart's
    bootstrap must NOT re-emit B's surviving store record (its content is a
    known, tombstoned put) — that would resurrect it fleet-wide with a
    fresh stamp."""
    sa, ra = _host(tmp_path, "a")
    shared = str(tmp_path / "shared")
    aa = SyncAgent(ra, FileTransport(shared))
    sa.put(dataclasses.replace(_rec(dims=(8,), obj=0.5, t=2),
                               created=time.time() - 3600))
    aa.sync_once()
    # host B gets the put the normal way...
    sb, rb = _host(tmp_path, "b")
    ab = SyncAgent(rb, FileTransport(shared))
    _quiesce(aa, ab)
    assert sb.get("k", ((8,),), "host") is not None
    # ...then A compacts (tombstone op) and B "crashes" mid-cycle: the
    # oplog ingests durably, the store never hears about it
    sa.compact(ttl_sec=60)
    aa.sync_once()
    b_log = OpLog(str(tmp_path / "b" / "store" / "fleet"))
    b_log.ingest(FileTransport(shared).pull(b_log))
    # restart B: bootstrap + one cycle must converge to "gone", and A must
    # not get the record back on its next pull
    sb2 = TuningStore(str(tmp_path / "b" / "store"))
    rb2 = Replica(sb2)
    ab2 = SyncAgent(rb2, FileTransport(shared))
    _quiesce(ab2, aa)
    assert sb2.get("k", ((8,),), "host") is None
    assert sa.get("k", ((8,),), "host") is None, "evicted record resurrected"


def test_file_transport_redelivers_ops_until_ingested(tmp_path):
    """pull() coverage is judged by the version vector, not a cursor: ops
    pulled by a cycle whose ingest failed must come back next cycle."""
    a = OpLog(str(tmp_path / "a"))
    a.emit("put", _rec(obj=1.0))
    t = FileTransport(str(tmp_path / "shared"))
    t.push(a)
    b = OpLog(str(tmp_path / "b"))
    first = t.pull(b)
    assert len(first) == 1
    assert len(t.pull(b)) == 1          # not ingested: delivered again
    b.ingest(first)
    assert t.pull(b) == []              # covered by the vv now


def test_http_ops_parsing_tolerates_foreign_lines():
    from repro.fleet.http import _ops_from_jsonl, _ops_to_jsonl

    good = Op(host="hA", seq=1, clock=1, kind="put", record=_rec(obj=1.0))
    data = (b'{"op": {"host": "hZ", "seq": 1, "clock": 1, "kind": "merge9000"}}\n'
            + b"not json at all\n" + _ops_to_jsonl([good]))
    assert _ops_from_jsonl(data) == [good]


def test_malformed_op_kind_rejected_before_durable_append(tmp_path):
    """An op with an unknown kind must die at the parse/ingest boundary —
    appended to the log it would crash every later replica startup."""
    op = Op(host="hX", seq=1, clock=1, kind="put", record=_rec(obj=1.0))
    bad = op.to_json()
    bad["op"]["kind"] = "putt"
    with pytest.raises(ValueError):
        Op.from_json(bad)
    log = OpLog(str(tmp_path / "fleet"))
    evil = dataclasses.replace(op, kind="putt")    # bypasses from_json
    applied, _ = log.ingest([evil, op])
    assert [o.kind for o in applied] == ["put"]
    assert len(OpLog(str(tmp_path / "fleet"))) == 1   # replay still works


# ---------------------------------------------------------------------------
# concurrency: interleaved writers during sync still converge
# ---------------------------------------------------------------------------


def test_concurrent_writers_during_sync_converge(tmp_path):
    sa, ra = _host(tmp_path, "a")
    sb, rb = _host(tmp_path, "b")
    shared = str(tmp_path / "shared")
    aa, ab = SyncAgent(ra, FileTransport(shared)), SyncAgent(rb, FileTransport(shared))
    stop = threading.Event()

    def writer(store: TuningStore, salt: int):
        rng = random.Random(salt)
        for i in range(30):
            dims = (rng.choice((8, 16, 32, 64)),)
            obj = round(rng.uniform(0.05, 1.0), 4)
            t = rng.choice((2, 4, 8, 16))
            if rng.random() < 0.1:
                store.quarantine(_rec(dims=dims, obj=obj, t=t))
            else:
                store.put(_rec(dims=dims, obj=obj, t=t))

    def syncer():
        while not stop.is_set():
            aa.sync_once()
            ab.sync_once()

    threads = [threading.Thread(target=writer, args=(s, i))
               for i, s in enumerate((sa, sa, sb, sb))]  # 4 writers, 2 per host
    sy = threading.Thread(target=syncer)
    sy.start()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    stop.set()
    sy.join()
    _quiesce(aa, ab, rounds=10)
    assert _contents(sa) == _contents(sb)
    # the merge is also what a fresh third host reconstructs from scratch
    sc, rc = _host(tmp_path, "c")
    _quiesce(SyncAgent(rc, FileTransport(shared)), aa, ab, rounds=10)
    assert _contents(sc) == _contents(sa)


# ---------------------------------------------------------------------------
# SyncAgent thread: hot swap into a live DispatchService + telemetry
# ---------------------------------------------------------------------------


def _toy_fleet_kernel():
    def _space(target="host", seed=1234):
        cs = ConfigurationSpace(seed=seed)
        cs.add_hyperparameter(Ordinal("s", (1, 2, 4, 8), default=1))
        return cs

    register("fleet_scale", builder=lambda cfg: lambda x: x * cfg["s"],
             space=_space)


def test_sync_agent_hot_swaps_replicated_config_into_service(tmp_path):
    _toy_fleet_kernel()
    sa, ra = _host(tmp_path, "a")
    sb = TuningStore(str(tmp_path / "b" / "store"))
    svc = DispatchService(sb)
    rb = Replica(sb, service=svc)
    shared = str(tmp_path / "shared")
    aa = SyncAgent(ra, FileTransport(shared))
    ab = SyncAgent(rb, FileTransport(shared), interval_sec=0.05)

    x = np.arange(4.0)
    np.testing.assert_array_equal(np.asarray(svc.call("fleet_scale", x)), x)
    assert svc.stats["store_default"] == 1

    sa.put(TuningRecord("fleet_scale", ((4,),), "host", {"s": 4}, 0.25))
    aa.sync_once()
    ab.start()
    try:
        deadline = time.monotonic() + 20
        while time.monotonic() < deadline:
            if sb.peek("fleet_scale", ((4,),), "host") is not None:
                break
            time.sleep(0.02)
        # the agent invalidated the cached executable: no manual invalidate
        np.testing.assert_array_equal(
            np.asarray(svc.call("fleet_scale", x)), x * 4)
        assert svc.stats["sync_applied"] >= 1
        tele = svc.telemetry()
        assert tele["sync_ops_pending"] == 0
        assert tele["sync_last_age_sec"] < 60
    finally:
        ab.stop()


def test_telemetry_merges_tuner_overhead_and_replication_lag(tmp_path):
    """DispatchService.telemetry() is the one dashboard view: dispatch
    counters + the tuner's ask/tell/wait seconds + sync lag, and a local
    background publish is pushed fleet-wide by the attached agent."""
    from repro.dispatch import BackgroundTuner

    _toy_fleet_kernel()
    store = TuningStore(str(tmp_path / "store"))
    tuner = BackgroundTuner(store, max_workers=1, max_evals=4, n_initial=2)
    svc = DispatchService(store, tuner=tuner)
    rep = Replica(store, service=svc)
    agent = SyncAgent(rep, FileTransport(str(tmp_path / "shared")))
    try:
        assert tuner.on_publish is not None        # attach_sync wired the nudge
        svc.dispatch("fleet_scale", np.arange(4.0))  # miss -> background tune
        tuner.drain()
        assert tuner.errors == []
        agent.sync_once()
        tele = svc.telemetry()
        assert tele["ask_sec"] > 0.0 and tele["campaigns"] == 1
        assert tele["sync_ops_pending"] == 0       # the publish was pushed
        assert tele["sync_published"] >= 1
        assert tele["sync_last_age_sec"] < 60
    finally:
        tuner.shutdown()


def test_sync_agent_survives_transport_failure(tmp_path):
    sa, ra = _host(tmp_path, "a")

    class BrokenTransport(FileTransport):
        def pull(self, oplog):
            raise OSError("shared dir unmounted")

    agent = SyncAgent(ra, BrokenTransport(str(tmp_path / "shared")))
    out = agent.sync_once()
    assert "error" in out
    assert agent.stats["sync_errors"] == 1 and len(agent.errors) == 1
    assert agent.lag()["sync_errors"] == 1


def test_status_reports_replication_lag(tmp_path):
    sa, ra = _host(tmp_path, "a")
    shared = str(tmp_path / "shared")
    t = FileTransport(shared)
    sa.put(_rec(dims=(8,), obj=0.5, t=2))
    st = ra.status(t)
    assert st["ops_pending"] == 1                  # emitted, not yet pushed
    assert st["records"] == 1 and st["ops"] == 1
    agent = SyncAgent(ra, t)
    agent.sync_once()
    st = ra.status(t)
    assert st["ops_pending"] == 0
    assert st["last_sync_age_sec"] is not None and st["last_sync_age_sec"] < 60


# ---------------------------------------------------------------------------
# HTTP push/pull pair
# ---------------------------------------------------------------------------


def test_http_transport_round_trip(tmp_path):
    from repro.fleet import FleetServer, HttpTransport

    sa, ra = _host(tmp_path, "a")
    sb, rb = _host(tmp_path, "b")
    server = FleetServer(ra).start()
    try:
        t = HttpTransport(server.url)
        sb.put(_rec(dims=(8,), obj=0.5, t=2))      # B pushes to A
        sa.put(_rec(dims=(16,), obj=0.7, t=4))     # B pulls from A
        agent = SyncAgent(rb, t)
        out = agent.sync_once()
        assert out == {"applied": 1, "published": 1, "pending": 0}
        assert sa.get("k", ((8,),), "host").config == {"t": 2}
        assert sb.get("k", ((16,),), "host").config == {"t": 4}
        assert t.pending(rb.oplog) == 0
    finally:
        server.stop()


def test_http_server_propagates_third_party_ops(tmp_path):
    # hub topology: A is the hub; B and C only talk to A, yet B's configs
    # reach C because /ops serves everything the hub knows
    from repro.fleet import FleetServer, HttpTransport

    sa, ra = _host(tmp_path, "a")
    sb, rb = _host(tmp_path, "b")
    sc, rc = _host(tmp_path, "c")
    server = FleetServer(ra).start()
    try:
        sb.put(_rec(dims=(8,), obj=0.5, t=2))
        SyncAgent(rb, HttpTransport(server.url)).sync_once()
        SyncAgent(rc, HttpTransport(server.url)).sync_once()
        assert sc.get("k", ((8,),), "host").config == {"t": 2}
    finally:
        server.stop()


# ---------------------------------------------------------------------------
# integration: warm starts + CLI
# ---------------------------------------------------------------------------


def test_warm_start_sees_replicated_neighbors(tmp_path):
    """A campaign warm-starts from records another host synced in moments
    ago — warm_start_material refreshes the store view itself."""
    store = TuningStore(str(tmp_path / "store"))
    assert warm_start_material(store, "k", ((8,),), "host") == (None, None)
    # another process view (the SyncAgent's reconcile) lands a record
    other = TuningStore(str(tmp_path / "store"))
    other.put(_rec(dims=(16,), obj=0.5, t=4))
    cfgs, recs = warm_start_material(store, "k", ((8,),), "host")
    assert cfgs == [{"t": 4}] and recs is None


def test_fleet_cli_sync_and_status(tmp_path, capsys):
    from repro.launch.fleet import main

    store_a = str(tmp_path / "a" / "store")
    store_b = str(tmp_path / "b" / "store")
    shared = f"file:{tmp_path / 'shared'}"
    TuningStore(store_a).put(_rec(dims=(8,), obj=0.5, t=2))
    assert main(["sync", "--store", store_a, "--transport", shared]) == 0
    assert main(["sync", "--store", store_b, "--transport", shared]) == 0
    assert TuningStore(store_b).get("k", ((8,),), "host").config == {"t": 2}
    capsys.readouterr()
    assert main(["status", "--store", store_b, "--transport", shared]) == 0
    import json

    st = json.loads(capsys.readouterr().out)
    assert st["records"] == 1 and st["ops_pending"] == 0
