"""Serving path: prefill/greedy decode consistency and cache accounting."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_reduced
from repro.models import forward, init_params
from repro.serve import cache_bytes_per_token, greedy_decode, make_serve_step, prefill

KEY = jax.random.PRNGKey(0)


def _cfg(arch):
    return dataclasses.replace(get_reduced(arch), dtype=jnp.float32)


def test_greedy_decode_runs_and_is_deterministic():
    cfg = _cfg("qwen2-0.5b")
    params = init_params(cfg, KEY)
    prompt = jax.random.randint(KEY, (2, 6), 0, cfg.vocab_size)
    out1 = greedy_decode(params, cfg, prompt, steps=5, max_len=16)
    out2 = greedy_decode(params, cfg, prompt, steps=5, max_len=16)
    assert out1.shape == (2, 5)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_prefill_cache_agrees_with_forward():
    cfg = _cfg("qwen1.5-0.5b")
    params = init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(4), (1, 8), 0, cfg.vocab_size)
    logits, cache = prefill(params, {"tokens": toks}, cfg, max_len=12)
    # next-step decode from the filled cache == forward on extended sequence
    serve = make_serve_step(cfg)
    nxt = jnp.argmax(logits[:, -1, :], -1).astype(toks.dtype)[:, None]
    _, step_logits, _ = serve(params, cache, nxt, 8)
    ext = jnp.concatenate([toks, nxt], axis=1)
    full_logits, _ = forward(params, {"tokens": ext}, cfg)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits[:, -1, :]),
                               atol=2e-2, rtol=2e-2)


def test_cache_bytes_accounting():
    # MLA's latent cache is dramatically smaller than GQA's at equal layers
    dsv2 = get_config("deepseek-v2-236b")
    mla = cache_bytes_per_token(dsv2)
    assert mla == (512 + 64) * 60 * 2
    # vs an MHA cache at the same head count and v_head_dim=128
    mha_equiv = 2 * dsv2.n_heads * dsv2.v_head_dim * dsv2.n_layers * 2
    assert mla < mha_equiv / 50  # the MLA compression claim (>50x here)

    assert cache_bytes_per_token(get_config("mamba2-780m")) == 0
    z = get_config("zamba2-1.2b")
    assert cache_bytes_per_token(z) == 2 * 32 * 64 * 7 * 2  # 7 shared sites


def test_serve_step_emits_argmax_token():
    cfg = _cfg("mamba2-780m")
    params = init_params(cfg, KEY)
    from repro.models import init_cache
    cache = init_cache(cfg, 2, 8)
    serve = make_serve_step(cfg)
    tok = jnp.zeros((2, 1), jnp.int32)
    nxt, logits, _ = serve(params, cache, tok, 0)
    np.testing.assert_array_equal(
        np.asarray(nxt[:, 0]), np.asarray(jnp.argmax(logits, -1)))
