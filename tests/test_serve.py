"""Serving path: prefill/greedy decode consistency and cache accounting."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_reduced
from repro.models import forward, init_params
from repro.serve import cache_bytes_per_token, greedy_decode, make_serve_step, prefill

KEY = jax.random.PRNGKey(0)


def _cfg(arch):
    return dataclasses.replace(get_reduced(arch), dtype=jnp.float32)


def test_greedy_decode_runs_and_is_deterministic():
    cfg = _cfg("qwen2-0.5b")
    params = init_params(cfg, KEY)
    prompt = jax.random.randint(KEY, (2, 6), 0, cfg.vocab_size)
    out1 = greedy_decode(params, cfg, prompt, steps=5, max_len=16)
    out2 = greedy_decode(params, cfg, prompt, steps=5, max_len=16)
    assert out1.shape == (2, 5)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))


def test_prefill_cache_agrees_with_forward():
    cfg = _cfg("qwen1.5-0.5b")
    params = init_params(cfg, KEY)
    toks = jax.random.randint(jax.random.PRNGKey(4), (1, 8), 0, cfg.vocab_size)
    logits, cache = prefill(params, {"tokens": toks}, cfg, max_len=12)
    # next-step decode from the filled cache == forward on extended sequence
    serve = make_serve_step(cfg)
    nxt = jnp.argmax(logits[:, -1, :], -1).astype(toks.dtype)[:, None]
    _, step_logits, _ = serve(params, cache, nxt, 8)
    ext = jnp.concatenate([toks, nxt], axis=1)
    full_logits, _ = forward(params, {"tokens": ext}, cfg)
    np.testing.assert_allclose(np.asarray(step_logits),
                               np.asarray(full_logits[:, -1, :]),
                               atol=2e-2, rtol=2e-2)


def test_cache_bytes_accounting():
    # MLA's latent cache is dramatically smaller than GQA's at equal layers
    dsv2 = get_config("deepseek-v2-236b")
    mla = cache_bytes_per_token(dsv2)
    assert mla == (512 + 64) * 60 * 2
    # vs an MHA cache at the same head count and v_head_dim=128
    mha_equiv = 2 * dsv2.n_heads * dsv2.v_head_dim * dsv2.n_layers * 2
    assert mla < mha_equiv / 50  # the MLA compression claim (>50x here)

    assert cache_bytes_per_token(get_config("mamba2-780m")) == 0
    z = get_config("zamba2-1.2b")
    assert cache_bytes_per_token(z) == 2 * 32 * 64 * 7 * 2  # 7 shared sites


def test_greedy_decode_service_resolves_tuned_flash_record(tmp_path):
    """The serve-path dispatch contract: a store seeded with a tuned
    flash-attention record for the prefill shape signature is resolved
    (store_exact), and the dispatched path reproduces the un-dispatched
    tokens and logits."""
    from repro.dispatch import DispatchService, TuningRecord, TuningStore
    from repro.kernels.model_kernels import flash_attention_signature

    cfg = _cfg("qwen2-0.5b")
    params = init_params(cfg, KEY)
    B, S = 2, 6
    prompt = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    base_toks = greedy_decode(params, cfg, prompt, steps=4, max_len=12)
    base_logits, _ = forward(params, {"tokens": prompt}, cfg)

    store = TuningStore(str(tmp_path / "s"))
    # the GQA route dispatches per kv-head group: BH = batch * kv heads
    sig = flash_attention_signature(B * cfg.n_kv_heads, S, S, cfg.hd)
    assert store.put(TuningRecord("flash_attention", sig, "host",
                                  {"impl": "xla", "bq": 4, "bk": 4}, 1.0))
    svc = DispatchService(store)
    toks = greedy_decode(params, cfg, prompt, steps=4, max_len=12, service=svc)
    assert svc.stats["store_exact"] >= 1           # resolved by signature
    assert svc.stats["build_failed"] == 0          # tuned variant actually ran
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(base_toks))
    svc_logits, _ = forward(params, {"tokens": prompt}, cfg, service=svc)
    np.testing.assert_allclose(np.asarray(svc_logits), np.asarray(base_logits),
                               atol=1e-4, rtol=1e-4)


def test_greedy_decode_service_empty_store_uses_defaults(tmp_path):
    from repro.dispatch import DispatchService, TuningStore

    cfg = _cfg("qwen2-0.5b")
    params = init_params(cfg, KEY)
    prompt = jax.random.randint(KEY, (2, 6), 0, cfg.vocab_size)
    base = greedy_decode(params, cfg, prompt, steps=3, max_len=12)
    svc = DispatchService(TuningStore(str(tmp_path / "s")))
    toks = greedy_decode(params, cfg, prompt, steps=3, max_len=12, service=svc)
    assert svc.stats["store_default"] >= 1
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(base))


def test_greedy_decode_service_poisoned_record_degrades(tmp_path):
    from repro.dispatch import DispatchService, TuningRecord, TuningStore
    from repro.kernels.model_kernels import flash_attention_signature

    cfg = _cfg("qwen2-0.5b")
    params = init_params(cfg, KEY)
    B, S = 2, 6
    prompt = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    base = greedy_decode(params, cfg, prompt, steps=3, max_len=12)

    store = TuningStore(str(tmp_path / "s"))
    # the GQA route dispatches per kv-head group: BH = batch * kv heads
    sig = flash_attention_signature(B * cfg.n_kv_heads, S, S, cfg.hd)
    store.put(TuningRecord("flash_attention", sig, "host",
                           {"impl": "bogus", "bq": 4, "bk": 4}, 1.0))
    svc = DispatchService(store)
    toks = greedy_decode(params, cfg, prompt, steps=3, max_len=12, service=svc)
    # the static feasibility pass rejects impl="bogus" before any build is
    # attempted (invalid_choice:impl), so this counts as "infeasible", not
    # "build_failed" — degraded either way, did not raise
    assert svc.stats["infeasible"] >= 1
    assert svc.stats["build_failed"] == 0
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(base))
    # the poisoned record is quarantined, not re-served
    assert store.get("flash_attention", sig, "host") is None


def test_serve_step_emits_argmax_token():
    cfg = _cfg("mamba2-780m")
    params = init_params(cfg, KEY)
    from repro.models import init_cache
    cache = init_cache(cfg, 2, 8)
    serve = make_serve_step(cfg)
    tok = jnp.zeros((2, 1), jnp.int32)
    nxt, logits, _ = serve(params, cache, tok, 0)
    np.testing.assert_array_equal(
        np.asarray(nxt[:, 0]), np.asarray(jnp.argmax(logits, -1)))


# ---------------------------------------------------------------------------
# paged KV cache (continuous batching)
# ---------------------------------------------------------------------------


def test_cache_bytes_paged_rounding():
    from repro.serve import cache_bytes

    cfg = get_config("qwen2-0.5b")
    per = cache_bytes_per_token(cfg)
    assert cache_bytes(cfg, 2, 100) == per * 2 * 100
    # paged layout allocates whole pages: 100 tokens on 64-token pages = 128
    assert cache_bytes(cfg, 2, 100, page_size=64) == per * 2 * 128
    assert cache_bytes(cfg, 2, 128, page_size=64) == per * 2 * 128


def test_paged_cache_rejects_unsupported_archs():
    from repro.serve import PagedKVCache

    with pytest.raises(ValueError):
        PagedKVCache(_cfg("deepseek-v2-236b"), 2, 16)   # MLA latent cache
    with pytest.raises(ValueError):
        PagedKVCache(_cfg("gemma3-1b"), 2, 16)          # windowed ring cache
    with pytest.raises(ValueError):
        PagedKVCache(_cfg("qwen2-0.5b"), 2, 16, page_size=0)


def test_paged_decode_matches_per_request_greedy():
    """Continuous batching on bucketed views reproduces each request's
    solo greedy_decode tokens exactly — admit/view/writeback round-trip
    plus per-row positions change nothing."""
    from repro.serve import PagedKVCache

    cfg = _cfg("qwen2-0.5b")
    params = init_params(cfg, KEY)
    prompts = [jax.random.randint(jax.random.PRNGKey(31), (1, 5), 0,
                                  cfg.vocab_size),
               jax.random.randint(jax.random.PRNGKey(32), (1, 3), 0,
                                  cfg.vocab_size)]
    steps = 4
    pc = PagedKVCache(cfg, max_batch=4, max_len=16, page_size=8)
    base = [np.asarray(greedy_decode(params, cfg, p, steps=steps,
                                     max_len=pc.alloc)) for p in prompts]

    serve = make_serve_step(cfg)
    slots, toks = [0, 2], []
    for slot, p in zip(slots, prompts):
        logits, cache = prefill(params, {"tokens": p}, cfg, max_len=pc.alloc)
        pc.admit(slot, cache, p.shape[1])
        toks.append([int(jnp.argmax(logits[:, -1, :], -1)[0])])
    assert pc.active_slots() == slots
    cur = jnp.asarray([[t[-1]] for t in toks], jnp.int32)
    for _ in range(steps - 1):
        bucket = pc.seq_bucket(slots)
        view = pc.view(slots, bucket)
        nxt, _, view = serve(params, view, cur, pc.pos_vector(slots) + 1)
        pc.writeback(slots, bucket, view)
        pc.advance(slots)
        for i, t in enumerate(toks):
            t.append(int(nxt[i, 0]))
        cur = nxt
    for got, want in zip(toks, base):
        np.testing.assert_array_equal(np.asarray(got), want[0])


def test_paged_cache_accounting_and_telemetry(tmp_path):
    """stats() reports pages allocated (whole pages per sequence) vs tokens
    resident, and an attached cache surfaces under telemetry()['kv_cache']."""
    from repro.dispatch import DispatchService, TuningStore
    from repro.serve import PagedKVCache, init_cache

    cfg = _cfg("qwen2-0.5b")
    pc = PagedKVCache(cfg, max_batch=4, max_len=16, page_size=8)
    assert pc.alloc == 16
    pc.admit(1, init_cache(cfg, 1, 16, cfg.dtype), prompt_len=5)
    pc.admit(3, init_cache(cfg, 1, 16, cfg.dtype), prompt_len=11)
    st = pc.stats()
    assert st["slots_active"] == 2
    assert st["tokens_resident"] == 16
    assert st["pages_allocated"] == 1 + 2     # ceil(5/8) + ceil(11/8)
    assert st["page_occupancy"] == 16 / 24
    assert st["bytes_resident"] < st["bytes_allocated"] < st["bytes_backing"]
    # bucket covers the deepest sequence plus headroom, page-aligned
    assert pc.seq_bucket([1]) == 8
    assert pc.seq_bucket([1, 3]) == 16
    pc.release(1)
    assert pc.stats()["pages_allocated"] == 2

    svc = DispatchService(TuningStore(str(tmp_path / "s")))
    svc.attach_kv_cache(pc)
    assert svc.telemetry()["kv_cache"]["page_size"] == 8


def test_greedy_decode_service_resolves_tuned_decode_record(tmp_path):
    """The decode-path dispatch contract (ninth kernel): a store record at
    the decode signature — batch*kv_heads rows, seq = the cache bucket —
    resolves as store_exact, builds, and reproduces un-dispatched tokens."""
    from repro.dispatch import DispatchService, TuningRecord, TuningStore
    from repro.kernels.model_kernels import decode_attention_signature

    cfg = _cfg("qwen2-0.5b")
    params = init_params(cfg, KEY)
    B, S = 2, 6
    prompt = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    base = greedy_decode(params, cfg, prompt, steps=4, max_len=12)

    store = TuningStore(str(tmp_path / "s"))
    K = cfg.n_kv_heads
    sig = decode_attention_signature(B * K, cfg.n_heads // K, 12, cfg.hd)
    assert store.put(TuningRecord("decode_attention", sig, "host",
                                  {"impl": "xla", "bk": 8, "hg": 1,
                                   "page": 4}, 1.0))
    svc = DispatchService(store)
    toks = greedy_decode(params, cfg, prompt, steps=4, max_len=12, service=svc)
    assert svc.stats["store_exact"] >= 1
    assert svc.stats["build_failed"] == 0
    np.testing.assert_array_equal(np.asarray(toks), np.asarray(base))
