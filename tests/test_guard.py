"""repro.guard chaos suite: deterministic fault injection, hardened
evaluation (deadline / crash isolation / pathological slowdown), shadow
evaluation, the drift watcher, crash-consistency of every durable log, and
the SyncAgent's transport-failure backoff.

The acceptance story this file pins: every injected failure *degrades* —
a hung evaluator times out as a FailureObservation without stalling its
campaign, an injected latency regression is auto-quarantined with
fallback to the default config, a torn write loses no durable record —
and with guard features disabled, fixed-seed campaign trajectories are
bit-identical to the pre-guard engine.
"""

import random
import time

import numpy as np
import pytest

from repro.core import EvalResult
from repro.core.database import FAILED, OK
from repro.core.jsonl import append_jsonl, iter_jsonl_tail
from repro.core.plopper import PENALTY
from repro.core.space import ConfigurationSpace, Ordinal
from repro.dispatch import DispatchService, TuningRecord, TuningStore
from repro.dispatch.registry import register
from repro.engine import Campaign
from repro.guard import (
    CATALOG,
    FailureObservation,
    FaultInjected,
    GuardAgent,
    HardenPolicy,
    HardenedExecutor,
    ShadowPolicy,
    WatchPolicy,
    clear_faults,
    fault_point,
    inject,
    install_env_faults,
    replay_decisions,
    window_stats,
)
from repro.guard.watch import _decide, _DriftState
from repro.obs.metrics import MetricsRegistry


@pytest.fixture(autouse=True)
def _no_leaked_faults():
    clear_faults()
    yield
    clear_faults()


def _space(seed=1234):
    cs = ConfigurationSpace(seed=seed)
    cs.add_hyperparameter(Ordinal("s", (1, 2, 4, 8, 16, 32), default=1))
    cs.add_hyperparameter(Ordinal("t", (1, 2, 4), default=1))
    return cs


def _det_eval(cfg):
    # deterministic "latency": minimized at s=32, t=4; no wall-clock noise
    return EvalResult(1.0 / (cfg["s"] * cfg["t"]), True, {})


def _toy_space(target="host", seed=1234):
    cs = ConfigurationSpace(seed=seed)
    cs.add_hyperparameter(Ordinal("s", (1, 2, 4, 8, 16, 32), default=1))
    return cs


register("toy_scale", builder=lambda cfg: lambda x: x * cfg["s"],
         space=_toy_space,
         make_evaluator=lambda factory: (
             lambda cfg: EvalResult(1.0 / cfg["s"], True, {})))


# ---------------------------------------------------------------------------
# fault harness
# ---------------------------------------------------------------------------


def test_fault_point_noop_when_unarmed():
    assert fault_point("eval.crash") is False
    assert fault_point("no.such.point") is False


def test_inject_times_and_every_are_deterministic():
    with inject("eval.crash", times=2) as fault:
        for _ in range(2):
            with pytest.raises(FaultInjected):
                fault_point("eval.crash")
        assert fault_point("eval.crash") is False  # budget spent
        assert fault.fired == 2
    assert fault_point("eval.crash") is False  # disarmed on exit

    with inject("dispatch.latency", every=3, delay_sec=0.0):
        fired = [fault_point("dispatch.latency") for _ in range(6)]
    assert fired == [False, False, True, False, False, True]


def test_inject_where_filters_by_context_substring():
    with inject("dispatch.latency", delay_sec=0.0, where={"kernel": "syr2k"}):
        assert fault_point("dispatch.latency", kernel="matmul") is False
        assert fault_point("dispatch.latency", kernel="syr2k") is True


def test_env_spec_parsing():
    n = install_env_faults(
        "eval.crash:times=1;dispatch.latency:delay=0.001,every=2,"
        "where.kernel=toy")
    assert n == 2
    with pytest.raises(FaultInjected):
        fault_point("eval.crash")
    assert fault_point("eval.crash") is False
    assert fault_point("dispatch.latency", kernel="toy") is False  # hit 1 of 2
    assert fault_point("dispatch.latency", kernel="toy") is True
    clear_faults()
    assert fault_point("dispatch.latency", kernel="toy") is False


def test_catalog_covers_the_documented_points():
    assert {"eval.hang", "eval.crash", "eval.slow", "dispatch.latency",
            "transport.flake", "transport.partition",
            "store.torn_write"} <= set(CATALOG)


# ---------------------------------------------------------------------------
# hardened evaluation
# ---------------------------------------------------------------------------


def test_crash_becomes_failure_observation_with_reason_code():
    def boom(cfg):
        raise ValueError("kaboom")

    ex = HardenedExecutor(boom, HardenPolicy())
    res = ex.submit({"s": 1}).result()
    assert res.ok is False
    assert res.objective == PENALTY
    assert res.info["failure"] == "exception"
    assert res.info["reason"] == "eval_crash:ValueError"
    assert ex.stats["crashes"] == 1


def test_campaign_survives_crashing_evaluator_and_penalizes_surrogate():
    calls = []

    def flaky(cfg):
        calls.append(dict(cfg))
        if cfg["s"] >= 16:  # a "region" of the space crashes
            raise RuntimeError("bad tile")
        return _det_eval(cfg)

    ex = HardenedExecutor(flaky, HardenPolicy(), metrics=MetricsRegistry())
    result = Campaign(_space(), executor=ex, max_evals=12, seed=7,
                      n_initial=4).run()
    db = result.db
    assert len(db) == 12  # every crash consumed budget as data, no retries
    failed = [r for r in db.records if r.status == FAILED]
    assert failed, "the crashing region must appear as FAILED records"
    for r in failed:
        assert r.objective == PENALTY  # the surrogate sees the penalty
        assert r.info["reason"] == "eval_crash:RuntimeError"
    # the campaign's best is a real measurement from the healthy region
    assert result.best is not None and result.best.config["s"] < 16


def test_hung_evaluator_times_out_without_stalling_campaign():
    def ev(cfg):
        return _det_eval(cfg)

    with inject("eval.hang", times=1, hang_max_sec=30.0):
        ex = HardenedExecutor(ev, HardenPolicy(deadline_sec=0.25))
        t0 = time.monotonic()
        result = Campaign(_space(), executor=ex, max_evals=6, seed=7,
                          n_initial=3).run()
        wall = time.monotonic() - t0
    assert wall < 10.0, "a hung evaluation must not stall the campaign"
    db = result.db
    assert len(db) == 6
    timeouts = [r for r in db.records
                if r.status == FAILED and r.info.get("failure") == "timeout"]
    assert len(timeouts) == 1
    assert timeouts[0].info["reason"] == "eval_timeout:0.25s"
    # timeout penalty is region-informative (deadline x scale), not PENALTY
    assert timeouts[0].objective == pytest.approx(0.25 * 10.0)
    ok = [r for r in db.records if r.status == OK]
    assert len(ok) == 5, "remaining evaluations must complete normally"


def test_pathological_slowdown_reclassified_keeping_measurement():
    def ev(cfg):
        return EvalResult(5.0 if cfg["s"] == 1 else 0.001, True, {})

    ex = HardenedExecutor(ev, HardenPolicy(baseline_sec=0.001,
                                           slowdown_factor=50.0))
    res = ex.submit({"s": 1, "t": 1}).result()
    assert res.ok is False
    assert res.info["failure"] == "pathological"
    assert res.info["reason"].startswith("pathological_slowdown:")
    assert res.objective == 5.0  # the measurement is already its own penalty
    assert ex.submit({"s": 2, "t": 1}).result().ok is True


def test_fixed_seed_trajectory_bit_identical_with_guard_disabled():
    """The acceptance pin: a HardenedExecutor with no deadline and
    parallel=1 (guard features effectively off) reproduces the plain
    inline engine's trajectory bit for bit."""
    base = Campaign(_space(), _det_eval, max_evals=14, seed=42,
                    n_initial=4).run()
    hardened = Campaign(_space(), executor=HardenedExecutor(
        _det_eval, HardenPolicy()), max_evals=14, seed=42, n_initial=4).run()
    assert [(r.config, r.objective, r.status) for r in base.db.records] == \
           [(r.config, r.objective, r.status) for r in hardened.db.records]
    assert base.best.config == hardened.best.config
    assert base.best.objective == hardened.best.objective


# ---------------------------------------------------------------------------
# shadow evaluation
# ---------------------------------------------------------------------------


def _service(tmp_path, **kw):
    store = TuningStore(str(tmp_path / "store"))
    return DispatchService(store, metrics=MetricsRegistry(), **kw), store


def test_shadow_eval_tells_live_measurement_into_store(tmp_path):
    svc, store = _service(tmp_path)
    # absurdly slow stored objective: the first live measurement improves it
    store.put(TuningRecord("toy_scale", ((4,),), "host", {"s": 2}, 10.0))
    guard = GuardAgent(svc, shadow=ShadowPolicy(epsilon=1.0,
                                                challenger_fraction=0.0))
    svc.attach_guard(guard)
    x = np.arange(4.0)
    fn = svc.dispatch("toy_scale", x)
    for _ in range(3):
        np.testing.assert_array_equal(np.asarray(fn(x)), x * 2)
    stats = guard.shadow.snapshot_stats()
    assert stats["shadow_evals"] >= 1
    assert stats["shadow_tells"] >= 1
    rec = store.get("toy_scale", ((4,),), "host")
    assert rec.source == "shadow"
    assert rec.objective < 10.0  # sharpened by live traffic
    assert rec.config == {"s": 2}


def test_shadow_challenger_races_and_counts(tmp_path):
    svc, store = _service(tmp_path)
    store.put(TuningRecord("toy_scale", ((4,),), "host", {"s": 2}, 10.0))
    guard = GuardAgent(svc, shadow=ShadowPolicy(epsilon=1.0,
                                                challenger_fraction=1.0,
                                                seed=3))
    svc.attach_guard(guard)
    x = np.arange(4.0)
    fn = svc.dispatch("toy_scale", x)
    for _ in range(4):
        fn(x)
    stats = guard.shadow.snapshot_stats()
    assert stats["challenger_evals"] >= 1
    assert stats["shadow_errors"] == 0


def test_shadow_epsilon_zero_never_samples(tmp_path):
    svc, store = _service(tmp_path)
    store.put(TuningRecord("toy_scale", ((4,),), "host", {"s": 2}, 10.0))
    guard = GuardAgent(svc, shadow=ShadowPolicy(epsilon=0.0))
    svc.attach_guard(guard)
    x = np.arange(4.0)
    fn = svc.dispatch("toy_scale", x)
    for _ in range(5):
        fn(x)
    assert guard.shadow.snapshot_stats()["shadow_evals"] == 0
    assert store.get("toy_scale", ((4,),), "host").objective == 10.0


# ---------------------------------------------------------------------------
# drift watch
# ---------------------------------------------------------------------------


class _StubTuner:
    """Records re-campaign submissions without running any."""

    def __init__(self):
        self.submitted = []
        self.stats = {}

    def submit(self, kernel, signature, backend, **kw):
        self.submitted.append((kernel, signature, backend))
        return object()


def test_drift_quarantines_falls_back_and_requests_retune(tmp_path):
    svc, store = _service(tmp_path, tuner=_StubTuner())
    store.put(TuningRecord("toy_scale", ((4,),), "host", {"s": 2}, 1e-4))
    guard = GuardAgent(svc, watch=WatchPolicy(
        drift_factor=50.0, hysteresis=2, cooldown_sec=0.0, min_samples=4))
    svc.attach_guard(guard)
    x = np.arange(4.0)
    fn = svc.dispatch("toy_scale", x)

    for _ in range(5):
        fn(x)
    assert guard.check_once() == []  # first check only sets the window base
    for _ in range(5):
        fn(x)
    assert guard.check_once() == []  # healthy window: no breach

    with inject("dispatch.latency", delay_sec=0.02):  # 200x the baseline
        for _ in range(5):
            fn(x)
        assert guard.check_once() == []  # breach 1 of 2: hysteresis holds
        for _ in range(5):
            fn(x)
        decisions = guard.check_once()  # breach 2: sustained drift -> act

    assert len(decisions) == 1
    d = decisions[0]
    assert d["action"] == "quarantine"
    assert d["reason"].startswith("drift:")
    assert d["config"] == {"s": 2}
    assert d["retune_requested"] is True
    # the ban is durable and machine-readable
    quars = store.quarantines("toy_scale")
    assert len(quars) == 1 and quars[0]["reason"].startswith("drift:")
    # a re-campaign for the exact live signature was enqueued immediately
    assert svc.tuner.submitted == [("toy_scale", ((4,),), "host")]
    # serving degraded: next dispatch resolves the default config
    before = svc.stats["store_default"]
    fn2 = svc.dispatch("toy_scale", x)
    assert fn2 is not fn
    assert svc.stats["store_default"] == before + 1
    np.testing.assert_array_equal(np.asarray(fn2(x)), x * 1)  # default s=1
    assert guard.stats["quarantines"] == 1
    assert guard.stats["fallbacks"] == 1


def test_drift_hysteresis_and_cooldown_pure_policy():
    policy = WatchPolicy(drift_factor=3.0, hysteresis=2, cooldown_sec=100.0,
                         min_samples=1)
    key = ("k", "4", "host")
    breach = {key: {"count": 10, "sum": 1.0, "p50": 1.0, "p99": 2.0}}
    healthy = {key: {"count": 10, "sum": 0.001, "p50": 1e-4, "p99": 1e-4}}
    baselines = {key: 1e-3}
    states = {}
    # one breach window is noise, not drift
    assert _decide(breach, baselines, states, policy, now=0.0) == []
    # a healthy window resets the streak
    assert _decide(healthy, baselines, states, policy, now=1.0) == []
    assert _decide(breach, baselines, states, policy, now=2.0) == []
    # two consecutive breaches fire exactly once...
    got = _decide(breach, baselines, states, policy, now=3.0)
    assert len(got) == 1 and got[0]["reason"] == "drift:1000.0x"
    # ...and the cooldown suppresses a re-fire until it expires
    _decide(breach, baselines, states, policy, now=4.0)
    assert _decide(breach, baselines, states, policy, now=5.0) == []
    assert len(_decide(breach, baselines, states, policy, now=103.0)) == 1


def test_unknown_baseline_is_ignored():
    policy = WatchPolicy(min_samples=1, hysteresis=1)
    windows = {("k", "4", "host"): {"count": 5, "sum": 5.0, "p50": 1.0,
                                    "p99": 1.0}}
    assert _decide(windows, {}, {}, policy, now=0.0) == []


def test_window_stats_are_deltas_not_cumulative():
    reg = MetricsRegistry()
    for _ in range(10):
        reg.observe("dispatch_execute_seconds", 1e-4, kernel="k",
                    signature="4", backend="host")
    snap1 = reg.snapshot()
    for _ in range(10):
        reg.observe("dispatch_execute_seconds", 0.05, kernel="k",
                    signature="4", backend="host")
    snap2 = reg.snapshot()
    cumulative = window_stats(None, snap2)[("k", "4", "host")]
    window = window_stats(snap1, snap2)[("k", "4", "host")]
    assert cumulative["count"] == 20 and window["count"] == 10
    # the fresh regression dominates the window p50 but not the cumulative
    assert window["p50"] > 10 * cumulative["p50"]


def test_replay_decisions_from_snapshot_log():
    reg = MetricsRegistry()
    lab = dict(kernel="k", signature="4", backend="host")
    for _ in range(8):
        reg.observe("dispatch_execute_seconds", 1e-4, **lab)
    snaps = [{"snapshot": reg.snapshot()}]
    for _ in range(2):  # two drifting windows
        for _ in range(8):
            reg.observe("dispatch_execute_seconds", 0.05, **lab)
        snaps.append({"snapshot": reg.snapshot()})
    got = replay_decisions(
        snaps, {("k", "4", "host"): 1e-4},
        WatchPolicy(drift_factor=3.0, hysteresis=2, cooldown_sec=0.0,
                    min_samples=4))
    assert len(got) == 1
    assert got[0]["window_index"] == 2
    assert got[0]["reason"].startswith("drift:")


def test_telemetry_guard_section(tmp_path):
    svc, store = _service(tmp_path)
    guard = GuardAgent(svc, shadow=ShadowPolicy(epsilon=0.5))
    svc.attach_guard(guard)
    guard.check_once()
    tel = svc.telemetry()
    assert tel["guard"]["checks"] == 1
    assert tel["guard"]["quarantines"] == 0
    assert "shadow" in tel["guard"]
    assert tel["guard"]["watching"]["hysteresis"] == guard.watch.hysteresis


def test_guard_agent_thread_lifecycle(tmp_path):
    svc, _store = _service(tmp_path)
    guard = GuardAgent(svc, watch=WatchPolicy(interval_sec=0.05))
    guard.start()
    deadline = time.monotonic() + 5.0
    while guard.stats["checks"] < 2 and time.monotonic() < deadline:
        time.sleep(0.01)
    guard.stop()
    assert guard.stats["checks"] >= 2
    assert guard.stats["watch_errors"] == 0


# ---------------------------------------------------------------------------
# crash consistency: torn writes lose no durable record
# ---------------------------------------------------------------------------


def test_torn_write_fault_point_tears_and_raises(tmp_path):
    p = str(tmp_path / "log.jsonl")
    append_jsonl(p, {"i": 0})
    with inject("store.torn_write", times=1):
        with pytest.raises(FaultInjected):
            append_jsonl(p, {"i": 1})
    # the torn fragment has no newline: the tail reader stops before it
    assert [o for o, _ in iter_jsonl_tail(p, 0)] == [{"i": 0}]


def test_store_recovers_all_durable_records_after_torn_write(tmp_path):
    path = str(tmp_path / "store")
    recs = [TuningRecord("toy_scale", ((4 * (i + 1),),), "host",
                         {"s": 2}, 0.5 + i) for i in range(4)]
    # kill the writer at every append position in turn
    for kill_at in range(1, 4):
        store = TuningStore(path + str(kill_at))
        for rec in recs[:kill_at]:
            assert store.put(rec)
        with inject("store.torn_write", times=1):
            with pytest.raises(FaultInjected):
                store.put(recs[kill_at])
        # a fresh process view: every record durable before the crash
        # survives, the torn line is isolated, and writes still work
        reopened = TuningStore(path + str(kill_at))
        assert len(reopened.records()) == kill_at
        assert reopened.put(recs[kill_at])
        assert len(reopened.records()) == kill_at + 1


def test_oplog_heals_missing_op_after_torn_write(tmp_path):
    from repro.fleet import Replica

    path = str(tmp_path / "store")
    store = TuningStore(path)
    rep = Replica(store)
    assert store.put(TuningRecord("toy_scale", ((4,),), "host", {"s": 2}, 0.5))
    ops_before = len(rep.oplog)
    # the op-sink append dies: store accepted the record, oplog missed it
    with inject("store.torn_write", times=1, where={"path": "fleet"}):
        with pytest.raises(FaultInjected):
            store.put(TuningRecord("toy_scale", ((8,),), "host", {"s": 4},
                                   0.25))
    assert len(store.records()) == 2  # the record itself IS durable
    # crash-restart: Replica bootstrap re-derives the missing op from the
    # store (ensure_put), so replication never loses the durable record
    store2 = TuningStore(path)
    rep2 = Replica(store2)
    assert len(store2.records()) == 2
    assert len(rep2.oplog) > ops_before
    keys = {k[:3] for k in rep2.oplog.merge_keys()}
    assert ("toy_scale", "8", "host") in keys


def test_obs_snapshot_log_recovers_after_torn_write(tmp_path):
    from repro.obs.export import read_snapshot_file, write_snapshot

    reg = MetricsRegistry()
    reg.add("guard_checks_total")
    p = str(tmp_path / "obs.jsonl")
    for _ in range(3):
        write_snapshot(p, registry=reg)
    with inject("store.torn_write", times=1):
        with pytest.raises(FaultInjected):
            write_snapshot(p, registry=reg)
    assert len(read_snapshot_file(p, merge=False)) == 3
    write_snapshot(p, registry=reg)  # repair_torn_tail isolates the fragment
    lines = read_snapshot_file(p, merge=False)
    assert len(lines) == 4
    merged = read_snapshot_file(p)
    assert merged["counters"][0]["name"] == "guard_checks_total"


# ---------------------------------------------------------------------------
# SyncAgent: transport failure classification + backoff
# ---------------------------------------------------------------------------


def _sync_agent(tmp_path, **kw):
    from repro.fleet import Replica, SyncAgent
    from repro.fleet.transport import transport_from_spec

    store = TuningStore(str(tmp_path / "store"))
    transport = transport_from_spec("file:" + str(tmp_path / "shared"))
    return SyncAgent(Replica(store), transport, **kw)


def test_transport_flake_is_classified_and_heals(tmp_path):
    agent = _sync_agent(tmp_path, interval_sec=0.1)
    with inject("transport.flake"):  # one ConnectionError, then healthy
        out = agent.sync_once()
        assert "error" in out and "ConnectionError" in out["error"]
    assert agent.stats["transport_errors"] == {"ConnectionError": 1}
    assert agent.stats["consecutive_failures"] == 1
    out = agent.sync_once()
    assert "error" not in out
    assert agent.stats["consecutive_failures"] == 0
    lag = agent.lag()
    assert lag["sync_transport_errors"] == {"ConnectionError": 1}
    assert lag["sync_consecutive_failures"] == 0


def test_transport_partition_keeps_failing_with_counts(tmp_path):
    agent = _sync_agent(tmp_path, interval_sec=0.1)
    with inject("transport.partition"):
        for _ in range(3):
            assert "error" in agent.sync_once()
    assert agent.stats["transport_errors"] == {"ConnectionError": 3}
    assert agent.stats["consecutive_failures"] == 3


def test_backoff_doubles_caps_and_jitters(tmp_path):
    agent = _sync_agent(tmp_path, interval_sec=1.0, backoff_jitter=0.0)
    assert agent._backoff_delay(0) == 1.0
    assert agent._backoff_delay(1) == 1.0
    assert agent._backoff_delay(3) == 4.0
    assert agent._backoff_delay(100) == 32.0  # capped at interval * 32
    jittered = _sync_agent(tmp_path / "j", interval_sec=1.0,
                           backoff_jitter=0.25, rng=random.Random(0))
    delays = {jittered._backoff_delay(3) for _ in range(8)}
    assert len(delays) > 1  # jitter de-synchronizes retries
    assert all(4.0 <= d <= 5.0 for d in delays)


def test_sync_status_exposes_error_classes(tmp_path):
    from repro.obs.metrics import get_registry, set_registry

    old = set_registry(MetricsRegistry())
    try:
        agent = _sync_agent(tmp_path, interval_sec=0.1)
        with inject("transport.partition"):
            agent.sync_once()
        status = agent.replica.status(agent.transport)
        assert status["counters"]["fleet_transport_errors"] == {
            "ConnectionError": 1}
    finally:
        set_registry(old)


# ---------------------------------------------------------------------------
# guarded background campaigns
# ---------------------------------------------------------------------------


def test_background_tuner_hardens_campaigns_and_skips_banned_configs(tmp_path):
    from repro.dispatch import BackgroundTuner, register

    crash_log = []

    def _guard_eval(cfg):
        if cfg["s"] == 32:
            crash_log.append(dict(cfg))
            raise RuntimeError("hot loop")
        return EvalResult(1.0 / cfg["s"], True, {})

    register("toy_guarded", builder=lambda cfg: lambda x: x * cfg["s"],
             space=lambda target="host": _space(),
             make_evaluator=lambda factory: _guard_eval)
    store = TuningStore(str(tmp_path / "store"))
    # pre-ban the config the campaign would otherwise publish (s=16,t=4 is
    # the best non-crashing config): the publish must fall to the next-best
    store.quarantine(TuningRecord("toy_guarded", ((4,),), "host",
                                  {"s": 16, "t": 4}, 1.0), reason="drift:9.9x")
    tuner = BackgroundTuner(store, max_evals=24, n_initial=6, seed=11,
                            harden=HardenPolicy(deadline_sec=10.0))
    fut = tuner.submit("toy_guarded", ((4,),), "host", space=_space(),
                       evaluator=_guard_eval)
    rec = fut.result(timeout=60)
    tuner.shutdown()
    assert not tuner.errors
    assert rec is not None
    assert rec.config != {"s": 16, "t": 4}, "banned config must not republish"
    assert store.get("toy_guarded", ((4,),), "host").config == rec.config
    if crash_log:  # the crashing region was explored and absorbed as data
        assert rec.config["s"] != 32
